//! The off-engine-thread retrieval runtime.
//!
//! PR 4 executed every cascade walk inline on the coordinator's engine
//! thread, so a long corpus search stalled pending distance-query
//! deadline flushes. PR 5 moved retrieval onto one dedicated thread —
//! which traded the engine stall for a *cross-tenant* stall: a
//! compaction or index build of corpus A blocked every search of
//! corpus B for its full duration. PR 8 removes that head-of-line
//! blocking while keeping the ordering contract that makes the
//! mutation API race-free:
//!
//! * Each registered corpus owns a **FIFO mailbox** holding its
//!   [`super::ShardedCorpus`] as actor state, executed by at most one
//!   dispatcher thread at a time (see [`super::dispatch`]). Jobs
//!   within one corpus therefore run **strictly in submission order**:
//!   a search never observes a half-applied insert/tombstone/compact,
//!   and a corpus invalidation (metric replacement) still fails every
//!   search queued behind it with "unknown corpus" while searches
//!   already dequeued complete against the snapshot they started with.
//! * A small pool of `sinkhorn-retrieval-{i}` dispatcher threads
//!   executes runnable mailboxes through two priority lanes: searches
//!   ride the fast lane and overtake registrations, mutations and
//!   compactions *of other corpora* — but never reorder against
//!   anything in their own corpus's mailbox. Intra-search parallelism
//!   (the [`super::ShardingConfig::threads`] scoped pool and each
//!   shard's refine executor workers) is unchanged.
//! * The engine thread keeps only validation and promise plumbing:
//!   every operation is a non-blocking submit carrying a completion
//!   callback, and results travel straight to the caller's promise
//!   channel without re-crossing the engine.
//! * Observability flows through a feedback channel
//!   ([`RuntimeFeedback`]): after every job the runtime pushes the
//!   search report, the pure off-thread search walltime, the dispatch
//!   queue wait (`queued_us` — the head-of-line blocking measure) and
//!   the per-shard gauges; invalidations push a tombstone feedback so
//!   the coordinator can purge the tenant's gauge rows.
//!   [`RetrievalRuntime::queue_depth`] exposes the total in-flight job
//!   count and [`RetrievalRuntime::corpus_depths`] the per-tenant
//!   backlog.
//! * A shard panic is contained twice over: the shard-level
//!   `catch_unwind` fails the triggering request with
//!   [`RetrievalError::ShardPanicked`], and the dispatcher's own
//!   safety net (a panic escaping the actor logic) drops that one
//!   corpus's state without poisoning its mailbox or taking down a
//!   dispatcher thread.
//!
//! Dropping the runtime drains every queued job (callers still get
//! their answers), then joins the dispatcher pool.

use super::dispatch::{DispatcherPool, Lane, MailboxJob};
use super::shard::{ShardGauges, ShardedCorpus, ShardingConfig};
use super::{Hit, RetrievalConfig, RetrievalError, RetrievalReport};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::trace::ctx::ActiveTrace;
use crate::trace::{Span, SpanData, Stage};
use crate::util::saturating_micros;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

/// Raw corpus key (the coordinator maps its `CorpusId` onto this; the
/// runtime is coordinator-agnostic). Also the mailbox key.
pub type CorpusKey = u32;
/// Raw metric key, used only to invalidate dependent corpora.
pub type MetricKey = u32;

/// Everything needed to build and install one sharded corpus.
pub struct RegisterSpec {
    /// Corpus key (re-registering an existing key replaces it).
    pub corpus: CorpusKey,
    /// Metric namespace the corpus depends on;
    /// [`RetrievalRuntime::drop_metric`] with this key invalidates the
    /// corpus.
    pub metric_key: MetricKey,
    /// The ground metric (owned: the runtime outlives the caller's
    /// borrow).
    pub metric: CostMatrix,
    /// Raw corpus entries; validated and indexed on a dispatcher thread.
    pub entries: Vec<Histogram>,
    /// Projection-anchor budget per shard index.
    pub anchors: usize,
    /// Search/refine configuration (shared by every shard).
    pub config: RetrievalConfig,
    /// Partitioning and search-concurrency knobs.
    pub sharding: ShardingConfig,
}

/// A completed off-thread search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Merged top-k in ascending `(distance, entry id)` order.
    pub hits: Vec<Hit>,
    /// Merged per-shard report.
    pub report: RetrievalReport,
    /// Queue wait + search walltime, µs (measured from the caller's
    /// submission instant).
    pub latency_us: u64,
}

/// Failures surfaced by runtime operations.
#[derive(Debug, Clone)]
pub enum RuntimeError {
    /// The corpus key is not registered (never was, or its metric was
    /// replaced and the corpus invalidated).
    UnknownCorpus(CorpusKey),
    /// The underlying index/search rejected the input.
    Index(RetrievalError),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::UnknownCorpus(key) => {
                write!(f, "retrieval corpus {key} is not registered")
            }
            RuntimeError::Index(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Index(e) => Some(e),
            _ => None,
        }
    }
}

/// One observability push from the runtime, emitted after every job
/// that addressed a corpus (searches, mutations, registrations,
/// invalidations).
#[derive(Debug, Clone)]
pub struct RuntimeFeedback {
    /// The corpus the job addressed.
    pub corpus: CorpusKey,
    /// The merged search report, for completed searches only.
    pub report: Option<RetrievalReport>,
    /// Pure search walltime on the dispatcher thread (µs, excludes
    /// queue wait); 0 for non-search jobs.
    pub search_us: u64,
    /// How long a search waited in its mailbox before dispatch (µs, 0
    /// for non-search jobs) — the head-of-line blocking measure. With
    /// per-corpus mailboxes this wait comes from the corpus's *own*
    /// queued jobs plus dispatcher contention, never from another
    /// tenant's serialized bulk work.
    pub queued_us: u64,
    /// Time spent building the sharded index inside a registration (µs,
    /// 0 for every other job). PR 9 closes the timing gap where index
    /// builds — the dominant bulk-lane occupant — were invisible: the
    /// coordinator accumulates this into
    /// [`crate::coordinator::CorpusGauges::build_us`].
    pub build_us: u64,
    /// Whether the job failed (unknown corpus or rejected input).
    pub failed: bool,
    /// The corpus stopped existing as a result of this job (metric
    /// invalidation, failed re-registration, or panic containment):
    /// consumers must purge the tenant's gauge rows instead of serving
    /// the last push forever.
    pub invalidated: bool,
    /// Per-shard gauges after the job (empty when the corpus is gone).
    pub gauges: Vec<ShardGauges>,
}

/// Completion callback carried by a job; invoked exactly once with the
/// job's outcome.
type Callback<T> = Box<dyn FnOnce(T) + Send>;

enum Job {
    Register(Box<RegisterSpec>, Callback<Result<usize, RetrievalError>>),
    Search {
        corpus: CorpusKey,
        query: Histogram,
        k: usize,
        enqueued: Instant,
        /// PR 9: sampled queries carry their trace across the mailbox
        /// hop (thread-locals don't cross the dispatcher boundary).
        trace: Option<ActiveTrace>,
        respond: Callback<Result<SearchOutcome, RuntimeError>>,
    },
    Insert {
        corpus: CorpusKey,
        entry: Histogram,
        respond: Callback<Result<usize, RuntimeError>>,
    },
    Tombstone {
        corpus: CorpusKey,
        entry: usize,
        respond: Callback<Result<bool, RuntimeError>>,
    },
    Compact {
        corpus: CorpusKey,
        respond: Callback<Result<usize, RuntimeError>>,
    },
    /// Broadcast into every mailbox so the invalidation lands in FIFO
    /// position: searches queued behind it fail, searches ahead of it
    /// complete against the old metric's snapshot.
    DropMetric(MetricKey),
    /// Test-only: arm the one-shot panic hook on one shard of a corpus
    /// (the shard's next search panics), exercising the containment
    /// contract end-to-end on a dispatcher thread.
    #[cfg(test)]
    Poison {
        corpus: CorpusKey,
        shard: usize,
        respond: Callback<bool>,
    },
    /// Test-only: occupy the mailbox — signal `entered`, then block
    /// until `gate` fires/drops. Lets tests pin one tenant's mailbox
    /// deterministically while asserting other tenants keep serving.
    #[cfg(test)]
    Hold {
        entered: Sender<()>,
        gate: std::sync::mpsc::Receiver<()>,
        respond: Callback<()>,
    },
}

impl MailboxJob for Job {
    fn lane(&self) -> Lane {
        match self {
            Job::Search { .. } => Lane::Fast,
            #[cfg(test)]
            Job::Poison { .. } => Lane::Fast,
            _ => Lane::Bulk,
        }
    }
}

/// Per-mailbox actor state: one tenant's sharded corpus plus the
/// metric namespace it depends on.
struct CorpusActor {
    metric_key: MetricKey,
    corpus: ShardedCorpus,
}

/// Handle to the mailbox-per-corpus dispatcher pool. All methods are
/// non-blocking submits; the `bool` return is kept for API continuity
/// and is always `true` while the handle lives (jobs cannot be lost —
/// drop drains before joining).
pub struct RetrievalRuntime {
    pool: DispatcherPool<Job, CorpusActor>,
    depth: Arc<AtomicUsize>,
    feedback: Sender<RuntimeFeedback>,
}

impl RetrievalRuntime {
    /// Spawn the runtime with an automatically sized dispatcher pool.
    /// Gauge/report pushes go to `feedback`; dropping the receiving end
    /// silently disables them.
    pub fn start(feedback: Sender<RuntimeFeedback>) -> Self {
        Self::with_dispatchers(feedback, 0)
    }

    /// Spawn the runtime with an explicit dispatcher-pool size.
    /// `dispatchers == 0` sizes to available parallelism clamped to
    /// `[2, 4]` — at least two threads, so one tenant's bulk job can
    /// never monopolize retrieval; `1` reproduces the PR 5 fully
    /// serialized behavior (modulo lane priority among *queued* jobs).
    pub fn with_dispatchers(feedback: Sender<RuntimeFeedback>, dispatchers: usize) -> Self {
        let dispatchers = if dispatchers == 0 {
            std::thread::available_parallelism().map_or(2, |n| n.get()).clamp(2, 4)
        } else {
            dispatchers
        };
        let depth = Arc::new(AtomicUsize::new(0));
        let ctx = RunnerCtx { feedback: feedback.clone(), depth: Arc::clone(&depth) };
        let hook_ctx = ctx.clone();
        let pool = DispatcherPool::new(
            dispatchers,
            Arc::clone(&depth),
            Arc::new(move |key, state, job| ctx.execute(key, state, job)),
            Arc::new(move |key| hook_ctx.contain_panic(key)),
        );
        Self { pool, depth, feedback }
    }

    /// Jobs accepted but not yet completed (queued + running), summed
    /// over every mailbox.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Per-corpus queued-job backlog, sorted by corpus key. Includes
    /// tenants whose registration is still queued; excludes idle
    /// tombstoned mailboxes.
    pub fn corpus_depths(&self) -> Vec<(CorpusKey, u64)> {
        self.pool
            .depths()
            .into_iter()
            .filter(|&(_, queued, registered)| registered || queued > 0)
            .map(|(key, queued, _)| (key, queued as u64))
            .collect()
    }

    /// Ready-lane backlogs `(fast, bulk)`: mailboxes whose head job is
    /// runnable but not yet claimed by a dispatcher thread. A sustained
    /// nonzero fast-lane depth means searches are waiting on dispatcher
    /// capacity, not on their own corpus's serialized work.
    pub fn lane_depths(&self) -> (usize, usize) {
        self.pool.lane_depths()
    }

    /// Number of dispatcher threads serving the mailbox pool.
    pub fn dispatchers(&self) -> usize {
        self.pool.workers()
    }

    /// Route a corpus-addressed job to its mailbox, failing the
    /// promise inline when no mailbox was ever created for the key
    /// (nothing is queued there, so the unknown-corpus answer is
    /// already in FIFO position).
    fn submit(&self, corpus: CorpusKey, job: Job) -> bool {
        if let Err(job) = self.pool.submit(corpus, job, false) {
            let _ = self.feedback.send(RuntimeFeedback {
                corpus,
                report: None,
                search_us: 0,
                queued_us: 0,
                build_us: 0,
                failed: true,
                invalidated: false,
                gauges: Vec::new(),
            });
            reject_unknown(corpus, job);
        }
        true
    }

    /// Build + install a sharded corpus; `ack` receives the indexed
    /// size (or the build error). Creates the corpus's mailbox.
    pub fn register(
        &self,
        spec: RegisterSpec,
        ack: Callback<Result<usize, RetrievalError>>,
    ) -> bool {
        let corpus = spec.corpus;
        self.pool
            .submit(corpus, Job::Register(Box::new(spec), ack), true)
            .unwrap_or_else(|_| unreachable!("submit with create cannot be rejected"));
        true
    }

    /// Merged pruned top-k against a registered corpus.
    pub fn search(
        &self,
        corpus: CorpusKey,
        query: Histogram,
        k: usize,
        enqueued: Instant,
        respond: Callback<Result<SearchOutcome, RuntimeError>>,
    ) -> bool {
        self.search_traced(corpus, query, k, enqueued, None, respond)
    }

    /// [`Self::search`] carrying an optional trace context for the
    /// sampled query; the dispatcher re-installs it on its own thread
    /// and emits mailbox/search/retrieve spans around the walk.
    pub(crate) fn search_traced(
        &self,
        corpus: CorpusKey,
        query: Histogram,
        k: usize,
        enqueued: Instant,
        trace: Option<ActiveTrace>,
        respond: Callback<Result<SearchOutcome, RuntimeError>>,
    ) -> bool {
        self.submit(corpus, Job::Search { corpus, query, k, enqueued, trace, respond })
    }

    /// Append one entry; the callback receives its fresh global id.
    pub fn insert(
        &self,
        corpus: CorpusKey,
        entry: Histogram,
        respond: Callback<Result<usize, RuntimeError>>,
    ) -> bool {
        self.submit(corpus, Job::Insert { corpus, entry, respond })
    }

    /// Tombstone one entry id; the callback receives whether a live
    /// entry was hit.
    pub fn tombstone(
        &self,
        corpus: CorpusKey,
        entry: usize,
        respond: Callback<Result<bool, RuntimeError>>,
    ) -> bool {
        self.submit(corpus, Job::Tombstone { corpus, entry, respond })
    }

    /// Compact every shard of the corpus holding tombstones; the
    /// callback receives how many shards rebuilt.
    pub fn compact(
        &self,
        corpus: CorpusKey,
        respond: Callback<Result<usize, RuntimeError>>,
    ) -> bool {
        self.submit(corpus, Job::Compact { corpus, respond })
    }

    /// Invalidate every corpus registered against `metric_key` (their
    /// precomputed statistics describe the replaced metric). The drop
    /// is broadcast into every mailbox, so per-corpus FIFO order is
    /// preserved: searches queued behind it fail with unknown-corpus.
    pub fn drop_metric(&self, metric_key: MetricKey) -> bool {
        self.pool.broadcast(|_| Job::DropMetric(metric_key));
        true
    }

    /// Test-only: arm the one-shot panic hook on `shard` of `corpus`.
    /// The callback receives whether the corpus was found.
    #[cfg(test)]
    fn poison(&self, corpus: CorpusKey, shard: usize, respond: Callback<bool>) -> bool {
        self.submit(corpus, Job::Poison { corpus, shard, respond })
    }

    /// Test-only: pin `corpus`'s mailbox with a blocking bulk job.
    /// Returns `(entered, gate, done)`: `entered` fires when the hold
    /// starts executing, dropping/sending `gate` releases it, `done`
    /// fires when it finishes.
    #[cfg(test)]
    fn hold(
        &self,
        corpus: CorpusKey,
    ) -> (
        std::sync::mpsc::Receiver<()>,
        Sender<()>,
        std::sync::mpsc::Receiver<()>,
    ) {
        let (entered_tx, entered_rx) = std::sync::mpsc::channel();
        let (gate_tx, gate_rx) = std::sync::mpsc::channel();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        self.submit(
            corpus,
            Job::Hold {
                entered: entered_tx,
                gate: gate_rx,
                respond: Box::new(move |()| drop(done_tx.send(()))),
            },
        );
        (entered_rx, gate_tx, done_rx)
    }
}

/// Settle a job whose corpus key has no mailbox: fail its promise with
/// unknown-corpus on the caller's thread.
fn reject_unknown(corpus: CorpusKey, job: Job) {
    match job {
        Job::Search { respond, .. } => respond(Err(RuntimeError::UnknownCorpus(corpus))),
        Job::Insert { respond, .. } => respond(Err(RuntimeError::UnknownCorpus(corpus))),
        Job::Tombstone { respond, .. } => respond(Err(RuntimeError::UnknownCorpus(corpus))),
        Job::Compact { respond, .. } => respond(Err(RuntimeError::UnknownCorpus(corpus))),
        Job::Register(..) | Job::DropMetric(_) => {
            unreachable!("register creates its mailbox; drop-metric is broadcast")
        }
        #[cfg(test)]
        Job::Poison { respond, .. } => respond(false),
        #[cfg(test)]
        Job::Hold { respond, .. } => respond(()),
    }
}

/// The per-job actor logic, shared by every dispatcher thread.
#[derive(Clone)]
struct RunnerCtx {
    feedback: Sender<RuntimeFeedback>,
    depth: Arc<AtomicUsize>,
}

impl RunnerCtx {
    /// Mark the current job complete *before* fulfilling its promise,
    /// so a caller that has observed its result never reads a stale
    /// non-zero queue depth for it.
    fn finish<T>(&self, respond: Callback<T>, value: T) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        respond(value);
    }

    #[allow(clippy::too_many_arguments)]
    fn push(
        &self,
        corpus: CorpusKey,
        state: &Option<CorpusActor>,
        report: Option<RetrievalReport>,
        search_us: u64,
        queued_us: u64,
        build_us: u64,
        failed: bool,
        invalidated: bool,
    ) {
        let gauges = state.as_ref().map(|a| a.corpus.gauges()).unwrap_or_default();
        let _ = self.feedback.send(RuntimeFeedback {
            corpus,
            report,
            search_us,
            queued_us,
            build_us,
            failed,
            invalidated,
            gauges,
        });
    }

    /// Dispatcher safety net: a job's unwind escaped the shard-level
    /// containment. The mailbox's state has already been dropped (the
    /// corpus degrades to unregistered); settle the accounting and
    /// tell the metrics layer to purge the tenant. The in-flight
    /// promise callback was consumed by the unwind — callers observe a
    /// disconnected promise channel, exactly as on shutdown.
    fn contain_panic(&self, corpus: CorpusKey) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        let _ = self.feedback.send(RuntimeFeedback {
            corpus,
            report: None,
            search_us: 0,
            queued_us: 0,
            build_us: 0,
            failed: true,
            invalidated: true,
            gauges: Vec::new(),
        });
    }

    fn execute(&self, key: CorpusKey, state: &mut Option<CorpusActor>, job: Job) {
        match job {
            Job::Register(spec, ack) => {
                let spec = *spec;
                debug_assert_eq!(spec.corpus, key, "register routed to the wrong mailbox");
                let t0 = Instant::now();
                let built = ShardedCorpus::new(
                    &spec.metric,
                    spec.entries,
                    spec.anchors,
                    spec.config,
                    spec.sharding,
                );
                let build_us = saturating_micros(t0.elapsed());
                match built {
                    Ok(corpus) => {
                        let size = corpus.len();
                        *state = Some(CorpusActor { metric_key: spec.metric_key, corpus });
                        self.push(key, state, None, 0, 0, build_us, false, false);
                        self.finish(ack, Ok(size));
                    }
                    Err(e) => {
                        // A failed (re-)registration must not leave a
                        // previous corpus under this key silently
                        // serving: the documented contract is that
                        // searches queued behind a failed rebuild get
                        // unknown-corpus, not stale data.
                        let invalidated = state.take().is_some();
                        self.push(key, state, None, 0, 0, build_us, true, invalidated);
                        self.finish(ack, Err(e));
                    }
                }
            }
            Job::Search { corpus, query, k, enqueued, trace, respond } => {
                let queued_us = saturating_micros(enqueued.elapsed());
                // Mailbox wait is real whether or not the corpus still
                // exists, so its span lands before the lookup.
                let dequeue_us = trace.as_ref().map(|t| {
                    let dequeue = t.sink.now_us();
                    t.sink.record(Span {
                        trace: t.trace,
                        stage: Stage::Mailbox,
                        tenant: t.tenant,
                        start_us: t.sink.instant_us(enqueued),
                        end_us: dequeue,
                        tid: 0,
                        data: SpanData::Mailbox { queued_us },
                    });
                    dequeue
                });
                let Some(actor) = state.as_mut() else {
                    self.push(corpus, state, None, 0, queued_us, 0, true, false);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let t0 = Instant::now();
                // Re-install the trace on this dispatcher thread so the
                // cascade/refine/shard layers below can see it.
                let guard =
                    trace.as_ref().map(|t| crate::trace::ctx::set_active(t.clone()));
                let outcome = actor.corpus.search(&query, k);
                drop(guard);
                let search_us = saturating_micros(t0.elapsed());
                match outcome {
                    Ok((hits, report)) => {
                        if let (Some(t), Some(dequeue)) = (&trace, dequeue_us) {
                            let end = t.sink.now_us();
                            t.sink.record(Span {
                                trace: t.trace,
                                stage: Stage::Search,
                                tenant: t.tenant,
                                start_us: dequeue,
                                end_us: end,
                                tid: 0,
                                data: SpanData::Search {
                                    hits: hits.len(),
                                    routed: report.routed,
                                    rescued: report.rescued,
                                },
                            });
                            // Root span: the whole client-observed
                            // retrieval, queue wait included.
                            t.sink.record(Span {
                                trace: t.trace,
                                stage: Stage::Retrieve,
                                tenant: t.tenant,
                                start_us: t.sink.instant_us(enqueued),
                                end_us: end,
                                tid: 0,
                                data: SpanData::None,
                            });
                        }
                        self.push(
                            corpus, state, Some(report), search_us, queued_us, 0, false, false,
                        );
                        let latency_us = saturating_micros(enqueued.elapsed());
                        self.finish(respond, Ok(SearchOutcome { hits, report, latency_us }));
                    }
                    Err(e) => {
                        self.push(corpus, state, None, search_us, queued_us, 0, true, false);
                        self.finish(respond, Err(RuntimeError::Index(e)));
                    }
                }
            }
            Job::Insert { corpus, entry, respond } => {
                let Some(actor) = state.as_mut() else {
                    self.push(corpus, state, None, 0, 0, 0, true, false);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let res = actor.corpus.insert(entry);
                let failed = res.is_err();
                self.push(corpus, state, None, 0, 0, 0, failed, false);
                self.finish(respond, res.map_err(RuntimeError::Index));
            }
            Job::Tombstone { corpus, entry, respond } => {
                let Some(actor) = state.as_mut() else {
                    self.push(corpus, state, None, 0, 0, 0, true, false);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let hit = actor.corpus.tombstone(entry);
                self.push(corpus, state, None, 0, 0, 0, false, false);
                self.finish(respond, Ok(hit));
            }
            Job::Compact { corpus, respond } => {
                let Some(actor) = state.as_mut() else {
                    self.push(corpus, state, None, 0, 0, 0, true, false);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let rebuilt = actor.corpus.compact();
                self.push(corpus, state, None, 0, 0, 0, false, false);
                self.finish(respond, Ok(rebuilt));
            }
            Job::DropMetric(metric_key) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                if state.as_ref().is_some_and(|a| a.metric_key == metric_key) {
                    *state = None;
                    // Tombstone push: the metrics layer purges this
                    // tenant's gauge rows instead of serving the last
                    // snapshot forever.
                    self.push(key, state, None, 0, 0, 0, false, true);
                }
            }
            #[cfg(test)]
            Job::Poison { shard, respond, .. } => {
                let armed = match state.as_mut() {
                    Some(actor) => {
                        actor.corpus.poison_shard(shard);
                        true
                    }
                    None => false,
                };
                self.finish(respond, armed);
            }
            #[cfg(test)]
            Job::Hold { entered, gate, respond } => {
                let _ = entered.send(());
                let _ = gate.recv();
                self.finish(respond, ());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Duration;

    fn spec(corpus: CorpusKey, seed: u64, shards: usize) -> (RegisterSpec, Histogram) {
        let d = 10;
        let mut rng = seeded_rng(seed);
        let metric = RandomMetric::new(d).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..18).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let q = Histogram::sample_uniform(d, &mut rng);
        let mut config = RetrievalConfig::serving(9.0);
        config.workers = 2;
        (
            RegisterSpec {
                corpus,
                metric_key: 7,
                metric,
                entries,
                anchors: 4,
                config,
                sharding: ShardingConfig { shards, threads: 2, ..Default::default() },
            },
            q,
        )
    }

    fn ack<T: Send + 'static>() -> (Callback<T>, Receiver<T>) {
        let (tx, rx) = channel();
        (Box::new(move |v| drop(tx.send(v))), rx)
    }

    #[test]
    fn register_search_mutate_and_feedback_round_trip() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(3, 0, 3);

        let (cb, rx) = ack();
        assert!(runtime.register(spec, cb));
        assert_eq!(rx.recv().unwrap().unwrap(), 18);

        let (cb, rx) = ack();
        assert!(runtime.search(3, q.clone(), 5, Instant::now(), cb));
        let outcome = rx.recv().unwrap().unwrap();
        assert_eq!(outcome.hits.len(), 5);
        assert_eq!(outcome.report.solved + outcome.report.pruned, 18);

        // Mutations serialize behind the search in submission order.
        let (cb, rx) = ack();
        assert!(runtime.insert(3, q.clone(), cb));
        let id = rx.recv().unwrap().unwrap();
        assert_eq!(id, 18, "fresh corpus-global id");
        let (cb, rx) = ack();
        assert!(runtime.tombstone(3, id, cb));
        assert!(rx.recv().unwrap().unwrap());
        let (cb, rx) = ack();
        assert!(runtime.compact(3, cb));
        assert!(rx.recv().unwrap().unwrap() >= 1);

        // Feedback: registration + search (with report) + 3 mutations.
        let mut reports = 0;
        let mut pushes = 0;
        while let Ok(fb) = fb_rx.try_recv() {
            pushes += 1;
            assert_eq!(fb.corpus, 3);
            assert!(!fb.failed);
            assert!(!fb.invalidated);
            if let Some(report) = fb.report {
                reports += 1;
                assert_eq!(report.k, 5);
                assert_eq!(fb.build_us, 0, "build time is registration-only");
                // Well-formedness, not wall-clock positivity: a
                // sub-microsecond search on a coarse clock is legal,
                // but the caller-observed latency always covers the
                // queue wait plus the search itself.
                assert!(outcome.latency_us >= fb.search_us);
                assert!(outcome.latency_us >= fb.queued_us);
            } else {
                assert_eq!(fb.queued_us, 0, "queue wait is search-only");
            }
            assert_eq!(fb.gauges.len(), 3, "per-shard gauges ride every push");
        }
        assert_eq!((pushes, reports), (5, 1));
        assert_eq!(runtime.queue_depth(), 0, "all jobs drained");
    }

    #[test]
    fn unknown_corpus_and_metric_invalidation() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(1, 1, 2);
        let metric_key = spec.metric_key;

        let (cb, rx) = ack();
        runtime.register(spec, cb);
        rx.recv().unwrap().unwrap();

        // A never-registered key fails cleanly.
        let (cb, rx) = ack();
        runtime.search(9, q.clone(), 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(9))
        ));

        // Replacing the metric invalidates the dependent corpus: the
        // search queued *behind* the invalidation fails, exactly as a
        // coordinator caller observes it.
        runtime.drop_metric(metric_key);
        let (cb, rx) = ack();
        runtime.search(1, q, 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(1))
        ));
        // Failed jobs are flagged in the feedback stream, and the
        // invalidation pushed a tombstone so the metrics layer can
        // purge corpus 1's gauge rows (PR 8 satellite fix).
        let mut failures = 0;
        let mut invalidations = Vec::new();
        while let Ok(fb) = fb_rx.try_recv() {
            failures += usize::from(fb.failed);
            if fb.invalidated {
                invalidations.push(fb.corpus);
                assert!(fb.gauges.is_empty(), "a dropped corpus has no gauges");
            }
        }
        assert_eq!(failures, 2);
        assert_eq!(invalidations, vec![1], "drop_metric must announce the purge");
    }

    #[test]
    fn failed_reregistration_drops_the_stale_corpus() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (good, q) = spec(5, 3, 2);
        let (cb, rx) = ack();
        runtime.register(good, cb);
        rx.recv().unwrap().unwrap();

        // Re-register the same key with a corpus that fails to build:
        // the caller sees the error AND the old corpus stops serving —
        // a swap that failed must not silently keep the old data live.
        let (mut bad, _) = spec(5, 3, 2);
        bad.entries[4] = Histogram::uniform(3);
        let (cb, rx) = ack();
        runtime.register(bad, cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RetrievalError::DimensionMismatch { entry: 4, got: 3, want: 10 })
        ));
        let (cb, rx) = ack();
        runtime.search(5, q, 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(5))
        ));
        // The failed swap announced the invalidation.
        let invalidated: Vec<CorpusKey> =
            fb_rx.try_iter().filter(|fb| fb.invalidated).map(|fb| fb.corpus).collect();
        assert_eq!(invalidated, vec![5]);
    }

    #[test]
    fn shard_panic_fails_one_request_not_the_runtime() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec_a, qa) = spec(1, 4, 3);
        let (spec_b, qb) = spec(2, 5, 2);
        let (cb, rx) = ack();
        runtime.register(spec_a, cb);
        rx.recv().unwrap().unwrap();
        let (cb, rx) = ack();
        runtime.register(spec_b, cb);
        rx.recv().unwrap().unwrap();

        // Poison one shard of corpus 1: the next search against it must
        // fail with the shard attributed — not unwind the dispatcher
        // thread serving both tenants, and not wedge corpus 1's
        // mailbox.
        let (cb, rx) = ack();
        assert!(runtime.poison(1, 1, cb));
        assert!(rx.recv().unwrap(), "corpus 1 must be found and armed");
        let (cb, rx) = ack();
        runtime.search(1, qa.clone(), 4, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::Index(RetrievalError::ShardPanicked { shard: 1 }))
        ));

        // The other tenant never noticed…
        let (cb, rx) = ack();
        runtime.search(2, qb, 3, Instant::now(), cb);
        assert_eq!(rx.recv().unwrap().unwrap().hits.len(), 3);
        // …and the poisoned corpus itself recovers on its next request.
        let (cb, rx) = ack();
        runtime.search(1, qa, 4, Instant::now(), cb);
        assert_eq!(rx.recv().unwrap().unwrap().hits.len(), 4);
        assert_eq!(runtime.queue_depth(), 0, "all jobs drained");
        // The failed search was flagged in the feedback stream.
        let mut failures = 0;
        while let Ok(fb) = fb_rx.try_recv() {
            failures += usize::from(fb.failed);
        }
        assert_eq!(failures, 1);
    }

    #[test]
    fn searches_overtake_another_tenants_inflight_bulk_job() {
        // Deterministic tenant isolation: pin corpus A's mailbox with a
        // blocking bulk job, then prove corpus B's search completes
        // while A is still held — the exact head-of-line blocking PR 8
        // removes — and that A's own queued search stays strictly
        // behind the hold (per-corpus FIFO).
        let (fb_tx, _fb_rx) = channel();
        let runtime = RetrievalRuntime::with_dispatchers(fb_tx, 2);
        let (spec_a, qa) = spec(1, 6, 2);
        let (spec_b, qb) = spec(2, 7, 2);
        let (cb, rx) = ack();
        runtime.register(spec_a, cb);
        rx.recv().unwrap().unwrap();
        let (cb, rx) = ack();
        runtime.register(spec_b, cb);
        rx.recv().unwrap().unwrap();

        let (entered, gate, done) = runtime.hold(1);
        entered.recv().expect("hold job started");
        // A search queued behind A's hold must NOT complete yet; B's
        // search must, on the free dispatcher.
        let (cb, a_rx) = ack();
        runtime.search(1, qa, 3, Instant::now(), cb);
        let (cb, b_rx) = ack();
        runtime.search(2, qb, 3, Instant::now(), cb);
        let b = b_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("tenant B blocked behind tenant A's in-flight bulk job")
            .unwrap();
        assert_eq!(b.hits.len(), 3);
        assert!(
            a_rx.try_recv().is_err(),
            "tenant A's search overtook its own queued bulk job"
        );
        let depths = runtime.corpus_depths();
        assert_eq!(depths.iter().find(|&&(k, _)| k == 1).map(|&(_, d)| d), Some(1));

        gate.send(()).expect("release hold");
        done.recv_timeout(Duration::from_secs(30)).expect("hold finished");
        assert_eq!(
            a_rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap().hits.len(),
            3
        );
        assert_eq!(runtime.queue_depth(), 0, "all jobs drained");
    }

    #[test]
    fn drop_drains_queued_jobs_before_joining() {
        let (fb_tx, _fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(0, 2, 1);
        let (cb, reg_rx) = ack();
        runtime.register(spec, cb);
        let (cb, search_rx) = ack();
        runtime.search(0, q, 3, Instant::now(), cb);
        drop(runtime);
        // Both promises were fulfilled during the drain.
        assert_eq!(reg_rx.recv().unwrap().unwrap(), 18);
        assert_eq!(search_rx.recv().unwrap().unwrap().hits.len(), 3);
    }
}
