//! The off-engine-thread retrieval runtime.
//!
//! PR 4 executed every cascade walk inline on the coordinator's engine
//! thread, so a long corpus search (or a brute-force recall probe)
//! stalled pending distance-query deadline flushes for its whole
//! duration. This module moves retrieval onto its own thread:
//!
//! * [`RetrievalRuntime`] spawns one dedicated `sinkhorn-retrieval`
//!   thread that owns every registered [`super::ShardedCorpus`] (index
//!   builds included — registration is also expensive). The engine
//!   thread keeps only validation and promise plumbing: every operation
//!   is a non-blocking channel send carrying a completion callback, and
//!   results travel straight to the caller's promise channel without
//!   re-crossing the engine.
//! * Jobs execute **in submission order** on the runtime thread, with
//!   intra-search parallelism across shards (the
//!   [`super::ShardingConfig::threads`] scoped pool) and across each
//!   shard's refine executor workers. Serialized jobs are what make the
//!   mutation API race-free without locks: a search never observes a
//!   half-applied insert/tombstone/compact, and a corpus invalidation
//!   (metric replacement) simply fails every search queued behind it
//!   with "unknown corpus" while searches already dequeued complete
//!   against the snapshot they started with.
//! * Observability flows through a feedback channel
//!   ([`RuntimeFeedback`]): after every job the runtime pushes the
//!   search report, the pure off-thread search walltime and the
//!   per-shard gauges; the coordinator drains it into its stats, and
//!   [`RetrievalRuntime::queue_depth`] exposes how many jobs are
//!   currently queued or running.
//!
//! Dropping the runtime handle disconnects the job channel; the thread
//! drains everything already queued (callers still get their answers)
//! and exits, and the drop joins it.

use super::shard::{ShardGauges, ShardedCorpus, ShardingConfig};
use super::{Hit, RetrievalConfig, RetrievalError, RetrievalReport};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Raw corpus key (the coordinator maps its `CorpusId` onto this; the
/// runtime is coordinator-agnostic).
pub type CorpusKey = u32;
/// Raw metric key, used only to invalidate dependent corpora.
pub type MetricKey = u32;

/// Everything needed to build and install one sharded corpus.
pub struct RegisterSpec {
    /// Corpus key (re-registering an existing key replaces it).
    pub corpus: CorpusKey,
    /// Metric namespace the corpus depends on;
    /// [`RetrievalRuntime::drop_metric`] with this key invalidates the
    /// corpus.
    pub metric_key: MetricKey,
    /// The ground metric (owned: the runtime outlives the caller's
    /// borrow).
    pub metric: CostMatrix,
    /// Raw corpus entries; validated and indexed on the runtime thread.
    pub entries: Vec<Histogram>,
    /// Projection-anchor budget per shard index.
    pub anchors: usize,
    /// Search/refine configuration (shared by every shard).
    pub config: RetrievalConfig,
    /// Partitioning and search-concurrency knobs.
    pub sharding: ShardingConfig,
}

/// A completed off-thread search.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Merged top-k in ascending `(distance, entry id)` order.
    pub hits: Vec<Hit>,
    /// Merged per-shard report.
    pub report: RetrievalReport,
    /// Queue wait + search walltime, µs (measured from the caller's
    /// submission instant).
    pub latency_us: u64,
}

/// Failures surfaced by runtime operations.
#[derive(Debug, Clone)]
pub enum RuntimeError {
    /// The corpus key is not registered (never was, or its metric was
    /// replaced and the corpus invalidated).
    UnknownCorpus(CorpusKey),
    /// The underlying index/search rejected the input.
    Index(RetrievalError),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::UnknownCorpus(key) => {
                write!(f, "retrieval corpus {key} is not registered")
            }
            RuntimeError::Index(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Index(e) => Some(e),
            _ => None,
        }
    }
}

/// One observability push from the runtime thread, emitted after every
/// job that addressed a corpus (searches, mutations, registrations).
#[derive(Debug, Clone)]
pub struct RuntimeFeedback {
    /// The corpus the job addressed.
    pub corpus: CorpusKey,
    /// The merged search report, for completed searches only.
    pub report: Option<RetrievalReport>,
    /// Pure search walltime on the runtime thread (µs, excludes queue
    /// wait); 0 for non-search jobs.
    pub search_us: u64,
    /// Whether the job failed (unknown corpus or rejected input).
    pub failed: bool,
    /// Per-shard gauges after the job (empty when the corpus is gone).
    pub gauges: Vec<ShardGauges>,
}

/// Completion callback carried by a job; invoked exactly once on the
/// runtime thread with the job's outcome.
type Callback<T> = Box<dyn FnOnce(T) + Send>;

enum Job {
    Register(Box<RegisterSpec>, Callback<Result<usize, RetrievalError>>),
    Search {
        corpus: CorpusKey,
        query: Histogram,
        k: usize,
        enqueued: Instant,
        respond: Callback<Result<SearchOutcome, RuntimeError>>,
    },
    Insert {
        corpus: CorpusKey,
        entry: Histogram,
        respond: Callback<Result<usize, RuntimeError>>,
    },
    Tombstone {
        corpus: CorpusKey,
        entry: usize,
        respond: Callback<Result<bool, RuntimeError>>,
    },
    Compact {
        corpus: CorpusKey,
        respond: Callback<Result<usize, RuntimeError>>,
    },
    DropMetric(MetricKey),
    /// Test-only: arm the one-shot panic hook on one shard of a corpus
    /// (the shard's next search panics), exercising the containment
    /// contract end-to-end on the runtime thread.
    #[cfg(test)]
    Poison {
        corpus: CorpusKey,
        shard: usize,
        respond: Callback<bool>,
    },
}

/// Handle to the dedicated retrieval thread. All methods are
/// non-blocking sends; they return `false` only when the runtime thread
/// is gone (the callback is then dropped uninvoked, which callers
/// observe as a disconnected promise channel).
pub struct RetrievalRuntime {
    tx: Option<Sender<Job>>,
    handle: Option<JoinHandle<()>>,
    depth: Arc<AtomicUsize>,
}

impl RetrievalRuntime {
    /// Spawn the runtime thread. Gauge/report pushes go to `feedback`;
    /// dropping the receiving end silently disables them.
    pub fn start(feedback: Sender<RuntimeFeedback>) -> Self {
        let (tx, rx) = channel();
        let depth = Arc::new(AtomicUsize::new(0));
        let thread_depth = Arc::clone(&depth);
        let handle = std::thread::Builder::new()
            .name("sinkhorn-retrieval".into())
            .spawn(move || {
                RuntimeThread {
                    corpora: HashMap::new(),
                    feedback,
                    depth: thread_depth,
                }
                .run(rx)
            })
            .expect("spawn retrieval runtime thread");
        Self { tx: Some(tx), handle: Some(handle), depth }
    }

    /// Jobs accepted but not yet completed (queued + the one running).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    fn send(&self, job: Job) -> bool {
        // Increment before the send so a completed job always finds the
        // count it must decrement.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.as_ref().map(|tx| tx.send(job)) {
            Some(Ok(())) => true,
            _ => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Build + install a sharded corpus; `ack` receives the indexed
    /// size (or the build error).
    pub fn register(
        &self,
        spec: RegisterSpec,
        ack: Callback<Result<usize, RetrievalError>>,
    ) -> bool {
        self.send(Job::Register(Box::new(spec), ack))
    }

    /// Merged pruned top-k against a registered corpus.
    pub fn search(
        &self,
        corpus: CorpusKey,
        query: Histogram,
        k: usize,
        enqueued: Instant,
        respond: Callback<Result<SearchOutcome, RuntimeError>>,
    ) -> bool {
        self.send(Job::Search { corpus, query, k, enqueued, respond })
    }

    /// Append one entry; the callback receives its fresh global id.
    pub fn insert(
        &self,
        corpus: CorpusKey,
        entry: Histogram,
        respond: Callback<Result<usize, RuntimeError>>,
    ) -> bool {
        self.send(Job::Insert { corpus, entry, respond })
    }

    /// Tombstone one entry id; the callback receives whether a live
    /// entry was hit.
    pub fn tombstone(
        &self,
        corpus: CorpusKey,
        entry: usize,
        respond: Callback<Result<bool, RuntimeError>>,
    ) -> bool {
        self.send(Job::Tombstone { corpus, entry, respond })
    }

    /// Compact every shard of the corpus holding tombstones; the
    /// callback receives how many shards rebuilt.
    pub fn compact(
        &self,
        corpus: CorpusKey,
        respond: Callback<Result<usize, RuntimeError>>,
    ) -> bool {
        self.send(Job::Compact { corpus, respond })
    }

    /// Invalidate every corpus registered against `metric_key` (their
    /// precomputed statistics describe the replaced metric). Searches
    /// queued behind this job fail with unknown-corpus.
    pub fn drop_metric(&self, metric_key: MetricKey) -> bool {
        self.send(Job::DropMetric(metric_key))
    }

    /// Test-only: arm the one-shot panic hook on `shard` of `corpus`.
    /// The callback receives whether the corpus was found.
    #[cfg(test)]
    fn poison(&self, corpus: CorpusKey, shard: usize, respond: Callback<bool>) -> bool {
        self.send(Job::Poison { corpus, shard, respond })
    }
}

impl Drop for RetrievalRuntime {
    fn drop(&mut self) {
        // Disconnect the job channel; the thread drains what is already
        // queued (promised answers still get delivered) and exits.
        drop(self.tx.take());
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// State owned by the runtime thread.
struct RuntimeThread {
    corpora: HashMap<CorpusKey, (MetricKey, ShardedCorpus)>,
    feedback: Sender<RuntimeFeedback>,
    depth: Arc<AtomicUsize>,
}

impl RuntimeThread {
    fn run(mut self, rx: Receiver<Job>) {
        while let Ok(job) = rx.recv() {
            self.handle(job);
        }
    }

    /// Mark the current job complete *before* fulfilling its promise,
    /// so a caller that has observed its result never reads a stale
    /// non-zero queue depth for it.
    fn finish<T>(&self, respond: Callback<T>, value: T) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
        respond(value);
    }

    fn push_feedback(
        &self,
        corpus: CorpusKey,
        report: Option<RetrievalReport>,
        search_us: u64,
        failed: bool,
    ) {
        let gauges = self
            .corpora
            .get(&corpus)
            .map(|(_, c)| c.gauges())
            .unwrap_or_default();
        let _ = self.feedback.send(RuntimeFeedback {
            corpus,
            report,
            search_us,
            failed,
            gauges,
        });
    }

    fn handle(&mut self, job: Job) {
        match job {
            Job::Register(spec, ack) => {
                let spec = *spec;
                match ShardedCorpus::new(
                    &spec.metric,
                    spec.entries,
                    spec.anchors,
                    spec.config,
                    spec.sharding,
                ) {
                    Ok(corpus) => {
                        let size = corpus.len();
                        self.corpora
                            .insert(spec.corpus, (spec.metric_key, corpus));
                        self.push_feedback(spec.corpus, None, 0, false);
                        self.finish(ack, Ok(size));
                    }
                    Err(e) => {
                        // A failed (re-)registration must not leave a
                        // previous corpus under this key silently
                        // serving: the documented contract is that
                        // searches queued behind a failed rebuild get
                        // unknown-corpus, not stale data.
                        self.corpora.remove(&spec.corpus);
                        self.push_feedback(spec.corpus, None, 0, true);
                        self.finish(ack, Err(e));
                    }
                }
            }
            Job::Search { corpus, query, k, enqueued, respond } => {
                let Some((_, sharded)) = self.corpora.get_mut(&corpus) else {
                    self.push_feedback(corpus, None, 0, true);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let t0 = Instant::now();
                let outcome = sharded.search(&query, k);
                let search_us =
                    t0.elapsed().as_micros().min(u64::MAX as u128) as u64;
                match outcome {
                    Ok((hits, report)) => {
                        self.push_feedback(corpus, Some(report), search_us, false);
                        let latency_us = enqueued
                            .elapsed()
                            .as_micros()
                            .min(u64::MAX as u128)
                            as u64;
                        self.finish(
                            respond,
                            Ok(SearchOutcome { hits, report, latency_us }),
                        );
                    }
                    Err(e) => {
                        self.push_feedback(corpus, None, search_us, true);
                        self.finish(respond, Err(RuntimeError::Index(e)));
                    }
                }
            }
            Job::Insert { corpus, entry, respond } => {
                let Some((_, sharded)) = self.corpora.get_mut(&corpus) else {
                    self.push_feedback(corpus, None, 0, true);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let res = sharded.insert(entry);
                let failed = res.is_err();
                self.push_feedback(corpus, None, 0, failed);
                self.finish(respond, res.map_err(RuntimeError::Index));
            }
            Job::Tombstone { corpus, entry, respond } => {
                let Some((_, sharded)) = self.corpora.get_mut(&corpus) else {
                    self.push_feedback(corpus, None, 0, true);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let hit = sharded.tombstone(entry);
                self.push_feedback(corpus, None, 0, false);
                self.finish(respond, Ok(hit));
            }
            Job::Compact { corpus, respond } => {
                let Some((_, sharded)) = self.corpora.get_mut(&corpus) else {
                    self.push_feedback(corpus, None, 0, true);
                    self.finish(respond, Err(RuntimeError::UnknownCorpus(corpus)));
                    return;
                };
                let rebuilt = sharded.compact();
                self.push_feedback(corpus, None, 0, false);
                self.finish(respond, Ok(rebuilt));
            }
            Job::DropMetric(metric_key) => {
                self.corpora.retain(|_, (mk, _)| *mk != metric_key);
                self.depth.fetch_sub(1, Ordering::Relaxed);
            }
            #[cfg(test)]
            Job::Poison { corpus, shard, respond } => {
                let armed = match self.corpora.get_mut(&corpus) {
                    Some((_, sharded)) => {
                        sharded.poison_shard(shard);
                        true
                    }
                    None => false,
                };
                self.finish(respond, armed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;
    use std::sync::mpsc::channel;

    fn spec(corpus: CorpusKey, seed: u64, shards: usize) -> (RegisterSpec, Histogram) {
        let d = 10;
        let mut rng = seeded_rng(seed);
        let metric = RandomMetric::new(d).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..18).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let q = Histogram::sample_uniform(d, &mut rng);
        let mut config = RetrievalConfig::serving(9.0);
        config.workers = 2;
        (
            RegisterSpec {
                corpus,
                metric_key: 7,
                metric,
                entries,
                anchors: 4,
                config,
                sharding: ShardingConfig { shards, threads: 2, ..Default::default() },
            },
            q,
        )
    }

    fn ack<T: Send + 'static>() -> (Callback<T>, Receiver<T>) {
        let (tx, rx) = channel();
        (Box::new(move |v| drop(tx.send(v))), rx)
    }

    #[test]
    fn register_search_mutate_and_feedback_round_trip() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(3, 0, 3);

        let (cb, rx) = ack();
        assert!(runtime.register(spec, cb));
        assert_eq!(rx.recv().unwrap().unwrap(), 18);

        let (cb, rx) = ack();
        assert!(runtime.search(3, q.clone(), 5, Instant::now(), cb));
        let outcome = rx.recv().unwrap().unwrap();
        assert_eq!(outcome.hits.len(), 5);
        assert_eq!(outcome.report.solved + outcome.report.pruned, 18);
        // Latency covers queue wait + search; both are sane.
        assert!(outcome.latency_us > 0);

        // Mutations serialize behind the search in submission order.
        let (cb, rx) = ack();
        assert!(runtime.insert(3, q.clone(), cb));
        let id = rx.recv().unwrap().unwrap();
        assert_eq!(id, 18, "fresh corpus-global id");
        let (cb, rx) = ack();
        assert!(runtime.tombstone(3, id, cb));
        assert!(rx.recv().unwrap().unwrap());
        let (cb, rx) = ack();
        assert!(runtime.compact(3, cb));
        assert!(rx.recv().unwrap().unwrap() >= 1);

        // Feedback: registration + search (with report) + 3 mutations.
        let mut reports = 0;
        let mut pushes = 0;
        while let Ok(fb) = fb_rx.try_recv() {
            pushes += 1;
            assert_eq!(fb.corpus, 3);
            assert!(!fb.failed);
            if let Some(report) = fb.report {
                reports += 1;
                assert_eq!(report.k, 5);
                assert!(fb.search_us > 0, "off-thread search walltime recorded");
            }
            assert_eq!(fb.gauges.len(), 3, "per-shard gauges ride every push");
        }
        assert_eq!((pushes, reports), (5, 1));
        assert_eq!(runtime.queue_depth(), 0, "all jobs drained");
    }

    #[test]
    fn unknown_corpus_and_metric_invalidation() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(1, 1, 2);
        let metric_key = spec.metric_key;

        let (cb, rx) = ack();
        runtime.register(spec, cb);
        rx.recv().unwrap().unwrap();

        // A never-registered key fails cleanly.
        let (cb, rx) = ack();
        runtime.search(9, q.clone(), 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(9))
        ));

        // Replacing the metric invalidates the dependent corpus: the
        // search queued *behind* the invalidation fails, exactly as a
        // coordinator caller observes it.
        runtime.drop_metric(metric_key);
        let (cb, rx) = ack();
        runtime.search(1, q, 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(1))
        ));
        // Failed jobs are flagged in the feedback stream.
        let mut failures = 0;
        while let Ok(fb) = fb_rx.try_recv() {
            failures += usize::from(fb.failed);
        }
        assert_eq!(failures, 2);
    }

    #[test]
    fn failed_reregistration_drops_the_stale_corpus() {
        let (fb_tx, _fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (good, q) = spec(5, 3, 2);
        let (cb, rx) = ack();
        runtime.register(good, cb);
        rx.recv().unwrap().unwrap();

        // Re-register the same key with a corpus that fails to build:
        // the caller sees the error AND the old corpus stops serving —
        // a swap that failed must not silently keep the old data live.
        let (mut bad, _) = spec(5, 3, 2);
        bad.entries[4] = Histogram::uniform(3);
        let (cb, rx) = ack();
        runtime.register(bad, cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RetrievalError::DimensionMismatch { entry: 4, got: 3, want: 10 })
        ));
        let (cb, rx) = ack();
        runtime.search(5, q, 2, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::UnknownCorpus(5))
        ));
    }

    #[test]
    fn shard_panic_fails_one_request_not_the_runtime() {
        let (fb_tx, fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec_a, qa) = spec(1, 4, 3);
        let (spec_b, qb) = spec(2, 5, 2);
        let (cb, rx) = ack();
        runtime.register(spec_a, cb);
        rx.recv().unwrap().unwrap();
        let (cb, rx) = ack();
        runtime.register(spec_b, cb);
        rx.recv().unwrap().unwrap();

        // Poison one shard of corpus 1: the next search against it must
        // fail with the shard attributed — not unwind the runtime
        // thread that owns both tenants.
        let (cb, rx) = ack();
        assert!(runtime.poison(1, 1, cb));
        assert!(rx.recv().unwrap(), "corpus 1 must be found and armed");
        let (cb, rx) = ack();
        runtime.search(1, qa.clone(), 4, Instant::now(), cb);
        assert!(matches!(
            rx.recv().unwrap(),
            Err(RuntimeError::Index(RetrievalError::ShardPanicked { shard: 1 }))
        ));

        // The other tenant never noticed…
        let (cb, rx) = ack();
        runtime.search(2, qb, 3, Instant::now(), cb);
        assert_eq!(rx.recv().unwrap().unwrap().hits.len(), 3);
        // …and the poisoned corpus itself recovers on its next request.
        let (cb, rx) = ack();
        runtime.search(1, qa, 4, Instant::now(), cb);
        assert_eq!(rx.recv().unwrap().unwrap().hits.len(), 4);
        assert_eq!(runtime.queue_depth(), 0, "all jobs drained");
        // The failed search was flagged in the feedback stream.
        let mut failures = 0;
        while let Ok(fb) = fb_rx.try_recv() {
            failures += usize::from(fb.failed);
        }
        assert_eq!(failures, 1);
    }

    #[test]
    fn drop_drains_queued_jobs_before_joining() {
        let (fb_tx, _fb_rx) = channel();
        let runtime = RetrievalRuntime::start(fb_tx);
        let (spec, q) = spec(0, 2, 1);
        let (cb, reg_rx) = ack();
        runtime.register(spec, cb);
        let (cb, search_rx) = ack();
        runtime.search(0, q, 3, Instant::now(), cb);
        drop(runtime);
        // Both promises were fulfilled during the drain.
        assert_eq!(reg_rx.recv().unwrap().unwrap(), 18);
        assert_eq!(search_rx.recv().unwrap().unwrap().hits.len(), 3);
    }
}
