//! Corpus-scale retrieval: pruned top-k Sinkhorn search.
//!
//! The paper's headline result is *retrieval* — Sinkhorn distances
//! beating classical OT and L2 on MNIST kNN — and a serving system asks
//! the retrieval question, not the pairwise one: *which of these corpus
//! histograms is closest to this query?* Answering it by brute force
//! costs one regularized solve per corpus entry; this module implements
//! the standard accelerator, a **bound-then-refine cascade** (Peyré &
//! Cuturi, *Computational Optimal Transport*, nearest-neighbor pruning
//! with 1-D projection / independence bounds):
//!
//! * [`CorpusIndex`] ingests, validates and normalizes the corpus and
//!   precomputes per-entry statistics (sorted projection CDFs, embedded
//!   barycenters, a per-entry warm-start cache);
//! * [`BoundCascade`] prices each candidate with cheap **admissible
//!   lower bounds** on d_M — and every bound on d_M also lower-bounds
//!   the served d_M^λ, because the entropic optimum is a feasible plan
//!   (d_M ≤ d_M^λ for every λ);
//! * [`RetrievalService`] keeps a top-k max-heap of served distances and
//!   prunes every candidate whose bound exceeds the running k-th best,
//!   re-ranking the survivors in panels through the
//!   [`crate::backend::ShardedExecutor`] so the refine stage rides the
//!   parallel workers, warm starts and kernel policies of PRs 1–3.
//!
//! Pruning is **exact**: the pruned top-k equals the brute-force top-k
//! (same distances, same order modulo ties) — locked down across kernel
//! policies, including truncated kernels where the rescue gate fires, by
//! `rust/tests/retrieval_exactness.rs`.
//!
//! PR 5 scales the pipeline past one index and off the serving thread:
//!
//! * [`ShardedCorpus`] partitions the corpus into [`CorpusShard`]s —
//!   each owning its own entry range, CDF tables, centroid coordinates,
//!   warm cache and refine executor — and merges the per-shard top-k
//!   heaps associatively, so shard results are order-independent (the
//!   precondition for future cross-machine placement);
//! * [`RetrievalRuntime`] runs every cascade walk, refine panel, index
//!   build and recall probe off the engine thread, turning the
//!   coordinator's retrieval entry points into non-blocking handoffs;
//! * the index is incrementally mutable: `insert` (one shard, O(d)),
//!   `tombstone` (O(1)) and threshold-triggered per-shard `compact`,
//!   with entry ids stable across the whole cycle.
//!
//! PR 7 adds the first deliberately *inexact* stage: an opt-in
//! per-shard ANN router ([`RoutingConfig`] on [`ShardingConfig`]) that
//! k-means-clusters the cached embedded-barycenter coordinates and
//! hands the exact cascade + refine only its shortlist to re-rank.
//! Recall is audited by the same merged-view probes; with routing
//! disabled (the default) the exact path is preserved bit-for-bit.
//!
//! PR 8 fixes the runtime's cross-tenant head-of-line blocking: instead
//! of one thread serializing *all* corpora, each corpus owns a FIFO
//! mailbox (the actor state is its [`ShardedCorpus`]) executed by a
//! small dispatcher pool (the private `dispatch` module) with two
//! priority lanes, so a
//! compaction or index build of corpus A no longer stalls searches of
//! corpus B while jobs within one corpus stay strictly serialized —
//! the per-corpus ordering contract is unchanged.
//!
//! The coordinator exposes the whole pipeline as a service API
//! (`DistanceService::register_corpus` / `retrieve` / `corpus_insert` /
//! `corpus_tombstone` / `corpus_compact`) with prune-fraction, recall,
//! per-shard and off-thread-latency gauges in its stats snapshot.

mod bounds;
mod dispatch;
mod index;
mod routing;
mod runtime;
mod search;
mod shard;

pub use bounds::{BoundCascade, BoundTier, BoundValue};
pub use index::{CorpusIndex, QueryPrep};
pub use routing::RoutingConfig;
pub use runtime::{
    CorpusKey, MetricKey, RegisterSpec, RetrievalRuntime, RuntimeError,
    RuntimeFeedback, SearchOutcome,
};
pub use search::{
    probe_outcome, Hit, ProbeOutcome, RetrievalConfig, RetrievalReport,
    RetrievalService,
};
pub use shard::{CorpusShard, ShardGauges, ShardedCorpus, ShardingConfig};

use crate::simplex::HistogramError;
use crate::F;

/// Check two top-k result lists for equivalence under the subsystem's
/// exactness contract: same distances position by position (relative
/// tolerance `tol`), and the same entry *sets* except across tie
/// boundaries — an entry appearing on only one side must tie, within
/// `tol`, with an entry appearing only on the other side, i.e. the two
/// sides may disagree solely about which member of a tied group made
/// the cut. Returns the first violation as an error string.
///
/// This is the single comparator behind the exactness test suite, the
/// retrieval bench's hard assert and external audits — one contract, no
/// drift.
pub fn topk_equivalent(got: &[Hit], want: &[Hit], tol: F) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("result sizes differ: {} vs {}", got.len(), want.len()));
    }
    for (pos, (a, b)) in got.iter().zip(want).enumerate() {
        if !(a.distance.is_finite() && b.distance.is_finite()) {
            return Err(format!(
                "pos {pos}: non-finite distance ({} vs {})",
                a.distance, b.distance
            ));
        }
        if (a.distance - b.distance).abs() > tol * (1.0 + b.distance.abs()) {
            return Err(format!(
                "pos {pos}: distance {} vs {}",
                a.distance, b.distance
            ));
        }
    }
    let got_set: std::collections::HashSet<usize> =
        got.iter().map(|h| h.entry).collect();
    let want_set: std::collections::HashSet<usize> =
        want.iter().map(|h| h.entry).collect();
    for (side, only, other, other_set) in [
        ("left", got, want, &want_set),
        ("right", want, got, &got_set),
    ] {
        for h in only.iter().filter(|h| !other_set.contains(&h.entry)) {
            let tied = other.iter().any(|w| {
                !only.iter().any(|x| x.entry == w.entry)
                    && (w.distance - h.distance).abs()
                        <= tol * (1.0 + w.distance.abs())
            });
            if !tied {
                return Err(format!(
                    "{side}-only entry {} (d={}) has no tie partner on the \
                     other side",
                    h.entry, h.distance
                ));
            }
        }
    }
    Ok(())
}

/// Errors raised while building or querying a retrieval index.
#[derive(Debug, Clone, PartialEq)]
pub enum RetrievalError {
    /// The corpus had no entries.
    EmptyCorpus,
    /// Corpus entry `entry` does not live on the metric's simplex.
    DimensionMismatch { entry: usize, got: usize, want: usize },
    /// Corpus row `entry` could not be normalized into a histogram.
    BadEntry { entry: usize, source: HistogramError },
    /// The query histogram does not live on the metric's simplex.
    QueryDimensionMismatch { got: usize, want: usize },
    /// A worker panicked inside shard `shard`'s cascade/refine. The
    /// panic is caught at the shard boundary and fails only the request
    /// that triggered it — the dispatcher thread executing the corpus's
    /// mailbox keeps serving, and no other tenant notices.
    ShardPanicked { shard: usize },
}

impl std::fmt::Display for RetrievalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RetrievalError::EmptyCorpus => {
                write!(f, "retrieval corpus must be non-empty")
            }
            RetrievalError::DimensionMismatch { entry, got, want } => write!(
                f,
                "corpus entry {entry} has dimension {got}, metric expects {want}"
            ),
            RetrievalError::BadEntry { entry, source } => {
                write!(f, "corpus entry {entry} is not a histogram: {source}")
            }
            RetrievalError::QueryDimensionMismatch { got, want } => write!(
                f,
                "query histogram has dimension {got}, corpus expects {want}"
            ),
            RetrievalError::ShardPanicked { shard } => write!(
                f,
                "retrieval shard {shard} panicked serving this request"
            ),
        }
    }
}

impl std::error::Error for RetrievalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RetrievalError::BadEntry { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hit(entry: usize, distance: F) -> Hit {
        Hit { entry, distance, rescued: false }
    }

    #[test]
    fn topk_equivalence_contract() {
        let a = [hit(3, 0.10), hit(7, 0.20), hit(1, 0.30)];
        // Identical lists agree.
        assert!(topk_equivalent(&a, &a, 1e-9).is_ok());
        // A tie swap at the cut (entry 9 vs 1 at the same distance) is
        // tolerated in both directions.
        let b = [hit(3, 0.10), hit(7, 0.20), hit(9, 0.30)];
        assert!(topk_equivalent(&a, &b, 1e-9).is_ok());
        assert!(topk_equivalent(&b, &a, 1e-9).is_ok());
        // A one-side-only entry without a tie partner is a violation,
        // even when every positional distance agrees: 8@0.20 (left only)
        // has no counterpart among the right-only entries (9@0.30).
        let c = [hit(3, 0.10), hit(8, 0.20), hit(1, 0.30)];
        let c2 = [hit(3, 0.10), hit(1, 0.20), hit(9, 0.30)];
        assert!(topk_equivalent(&c, &c2, 1e-9).is_err());
        // …as is a positional distance mismatch or a size mismatch.
        let d = [hit(3, 0.10), hit(7, 0.21), hit(1, 0.30)];
        assert!(topk_equivalent(&a, &d, 1e-9).is_err());
        assert!(topk_equivalent(&a, &a[..2], 1e-9).is_err());
        // Non-finite distances never pass.
        let e = [hit(3, 0.10), hit(7, 0.20), hit(1, F::NAN)];
        assert!(topk_equivalent(&e, &e, 1e-9).is_err());
    }
}
