//! Bound-then-refine top-k search over a [`CorpusIndex`].
//!
//! The query pipeline:
//!
//! 1. **price** every candidate with the [`BoundCascade`] (O(n·d));
//! 2. **seed** — solve the k candidates with the smallest bounds through
//!    the [`ShardedExecutor`] to establish the running k-th-best served
//!    distance τ (a top-k max-heap);
//! 3. **sweep** the remaining candidates in ascending bound order,
//!    re-ranking survivors in executor-wide panels; the first candidate
//!    whose lower bound exceeds τ (plus the admissibility slack) ends
//!    the walk — every candidate behind it is pruned without a solve,
//!    because bounds only grow along the walk and τ only shrinks.
//!
//! The refine stage rides the whole PR 1–3 substrate: panels shard
//! across the executor's workers, the kernel policy shapes each worker's
//! operator (truncated/low-rank panels route through the existing
//! rescue gate, so an infeasible-on-support pair always comes back
//! log-domain-exact rather than collapsed), and converged scalings are
//! deposited into the index's per-entry warm cache to seed future
//! queries.

use super::routing::Router;
use super::{BoundCascade, BoundTier, CorpusIndex, RetrievalError, RoutingConfig};
use crate::backend::{BackendKind, ShardedExecutor};
use crate::simplex::Histogram;
use crate::sinkhorn::{ScalingInit, SinkhornConfig, SinkhornOutput, SolveBudget};
use crate::trace::{ctx, PanelTrace, Span, SpanData, Stage};
use crate::F;
use std::collections::{BinaryHeap, HashMap};
use std::sync::Arc;

/// Refine/search knobs.
#[derive(Debug, Clone, Copy)]
pub struct RetrievalConfig {
    /// Solve configuration of the refine stage. Convergence-checked
    /// mode is strongly recommended (and is what
    /// [`Self::serving`] builds): it makes the truncated-kernel rescue
    /// contract total, so served distances are trustworthy at any
    /// kernel policy.
    pub sinkhorn: SinkhornConfig,
    /// Worker threads of the refine executor (each owning a private
    /// kernel-bound backend). 0 = available parallelism.
    pub workers: usize,
    /// Pinned refine backend; `None` routes like the coordinator
    /// ([`ShardedExecutor::auto`] — kernel-policy aware, log-domain on
    /// underflow).
    pub backend: Option<BackendKind>,
    /// Refine panel width (queries per executor dispatch). 0 = auto
    /// (4 shards per worker).
    pub panel: usize,
    /// Admissibility slack: a candidate is pruned only when its bound
    /// exceeds τ + slack·(1 + τ), absorbing solver-tolerance-level
    /// noise in the served distances the bounds are compared against.
    /// [`RetrievalService::new`] floors the effective slack at 10× the
    /// refine tolerance — the bounds are exact but τ is a *solved*
    /// value, so the slack must dominate the solver's own noise no
    /// matter how the tolerance is configured.
    pub bound_slack: F,
    /// Run a brute-force recall probe every N-th query (0 = never): the
    /// pruned top-k is recomputed without pruning and compared, and the
    /// outcome lands in the report / coordinator recall gauges.
    pub probe_every: u64,
    /// Seed refine solves from the index's per-entry warm cache and
    /// deposit converged scalings back.
    pub warm_start: bool,
    /// Anytime budget of the refine stage. [`SolveBudget::Unbounded`]
    /// (the default) reproduces the exact pre-anytime pipeline
    /// bit-identically. A bounded budget turns each refine panel into a
    /// certified cheap pass: candidates whose whole error interval
    /// clears the running τ are discarded without further work, and only
    /// the straddlers — candidates whose interval still contains τ — get
    /// a full solve.
    pub budget: SolveBudget,
}

impl RetrievalConfig {
    /// Serving defaults at `lambda`: convergence-checked refine
    /// (tolerance 1e-9, 10k-iteration cap), auto kernel policy, auto
    /// backend, warm starts on, probes off.
    pub fn serving(lambda: F) -> Self {
        Self {
            sinkhorn: SinkhornConfig {
                lambda,
                tolerance: 1e-9,
                max_iterations: 10_000,
                check_every: 1,
                auto_stabilize: true,
                schedule: crate::sinkhorn::LambdaSchedule::Fixed,
                kernel: crate::linalg::KernelPolicy::Auto,
            },
            workers: 0,
            backend: None,
            panel: 0,
            bound_slack: 1e-9,
            probe_every: 0,
            warm_start: true,
            budget: SolveBudget::Unbounded,
        }
    }
}

/// One retrieved neighbor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hit {
    /// Stable corpus entry id (ingestion order for a standalone
    /// service; the corpus-global id space under
    /// [`super::ShardedCorpus`]). Ids survive tombstone/compact cycles.
    pub entry: usize,
    /// Served distance d_M^λ(query, entry).
    pub distance: F,
    /// Whether the solve was *rerouted* through the exact log-domain
    /// path (truncated-support infeasibility or divergence — never a
    /// collapsed-column read-off). Always `false` when the refine class
    /// itself runs on the log-domain backend: there every solve is
    /// log-domain by design and nothing was rescued.
    pub rescued: bool,
}

/// Outcome of one brute-force recall probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeOutcome {
    /// Pruned-result entries confirmed by the brute-force top-k.
    pub matched: usize,
    /// Entries compared (the effective k).
    pub k: usize,
}

/// What one query cost and what the cascade saved.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetrievalReport {
    /// Live corpus entries priced at query time (tombstoned slots are
    /// invisible to the search).
    pub corpus: usize,
    /// Effective k (requested k clamped to the corpus size).
    pub k: usize,
    /// Candidates solved (seed + sweep panels).
    pub solved: usize,
    /// Candidates discarded on their lower bound alone.
    pub pruned: usize,
    /// Executor panel dispatches.
    pub panels: usize,
    /// Solves that went through the exact log-domain rescue.
    pub rescued: usize,
    /// Solves that came back non-finite (excluded from the top-k).
    pub failed: usize,
    /// Refine solves seeded from the per-entry warm cache.
    pub warm_seeded: usize,
    /// Total refine fixed-point iterations.
    pub iterations: usize,
    /// Budgeted candidates discarded because their whole certified
    /// interval cleared τ (0 on the unbounded path).
    pub pruned_interval: usize,
    /// Budgeted straddlers escalated to a full solve (0 on the
    /// unbounded path).
    pub refined: usize,
    /// Pruned candidates whose deciding bound was the mass tier.
    pub pruned_mass: usize,
    /// … the centroid tier.
    pub pruned_centroid: usize,
    /// … the projection tier.
    pub pruned_projection: usize,
    /// Final pruning threshold τ (the k-th best served distance).
    pub threshold: F,
    /// Whether the ANN router produced this query's candidate set (the
    /// exact every-live-entry walk was skipped).
    pub routed: bool,
    /// Candidates admitted to the priced shortlist. Equals `corpus`
    /// when routing is off; with routing on,
    /// `solved + pruned == shortlist` and `corpus - shortlist` entries
    /// were never priced at all.
    pub shortlist: usize,
    /// Recall-probe outcome, when one ran.
    pub probe: Option<ProbeOutcome>,
}

impl RetrievalReport {
    /// An empty report for a corpus of `n` entries and effective `k`
    /// (also the zero element of the sharded runtime's report merge).
    pub(crate) fn empty(corpus: usize, k: usize) -> Self {
        Self {
            corpus,
            k,
            solved: 0,
            pruned: 0,
            panels: 0,
            rescued: 0,
            failed: 0,
            warm_seeded: 0,
            iterations: 0,
            pruned_interval: 0,
            refined: 0,
            pruned_mass: 0,
            pruned_centroid: 0,
            pruned_projection: 0,
            threshold: F::INFINITY,
            routed: false,
            shortlist: 0,
            probe: None,
        }
    }

    /// Fraction of the corpus discarded without a solve.
    pub fn pruned_fraction(&self) -> f64 {
        if self.corpus == 0 {
            return 0.0;
        }
        self.pruned as f64 / self.corpus as f64
    }

    /// Fraction of the live corpus admitted to the priced shortlist
    /// (1.0 on an unrouted or empty search).
    pub fn shortlist_fraction(&self) -> f64 {
        if self.corpus == 0 || !self.routed {
            return 1.0;
        }
        self.shortlist as f64 / self.corpus as f64
    }
}

/// Max-heap item ordered by (distance, entry) so the canonical ascending
/// (distance, entry) order pops last.
#[derive(Debug, PartialEq)]
struct HeapItem {
    distance: F,
    entry: usize,
    rescued: bool,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.distance
            .total_cmp(&other.distance)
            .then(self.entry.cmp(&other.entry))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Pruned top-k retrieval over one corpus: the cascade prices, the
/// executor refines.
///
/// Entries are addressed by *stable ids*: a standalone service numbers
/// them `0..n` in ingestion order, while a shard inside
/// [`super::ShardedCorpus`] speaks a disjoint slice of one global id
/// space ([`Self::with_base`]). Ids survive [`Self::tombstone`] /
/// [`Self::compact`] cycles — compaction renumbers internal index slots
/// but never the ids results and mutations are keyed by.
pub struct RetrievalService {
    index: CorpusIndex,
    cascade: BoundCascade,
    executor: ShardedExecutor,
    config: RetrievalConfig,
    queries: u64,
    /// Caller-stable entry id per index slot.
    globals: Vec<usize>,
    /// Reverse map: stable id → index slot (tombstoned slots included
    /// until compaction).
    local_of: HashMap<usize, usize>,
    /// Tombstone flag per index slot; tombstoned slots are skipped by
    /// every search and reclaimed by [`Self::compact`].
    tombstones: Vec<bool>,
    /// Live (non-tombstoned) slot count.
    live: usize,
    /// Requested ANN routing knobs (`None` = exact path, the default).
    routing: Option<RoutingConfig>,
    /// The built k-means router; `None` whenever routing is disabled
    /// *or* the metric does not factor (no centroid coordinate space).
    router: Option<Router>,
    /// One-shot test hook: the next [`Self::top_k`] panics instead of
    /// searching, exercising the sharded runtime's panic containment.
    #[cfg(any(test, debug_assertions))]
    poison_next_search: bool,
}

impl RetrievalService {
    /// Bind a retrieval service to an index. The refine executor is
    /// built from the config: `workers` private backend instances of
    /// the pinned kind, or the policy-aware auto route.
    pub fn new(index: CorpusIndex, config: RetrievalConfig) -> Self {
        Self::with_base(index, config, 0)
    }

    /// Like [`Self::new`], but entry ids start at `base`: hits and the
    /// mutation API speak ids `base..base + len`. The sharded runtime
    /// uses this to give each shard a disjoint slice of one corpus-wide
    /// id space, so per-shard top-k heaps merge without translation.
    pub fn with_base(index: CorpusIndex, config: RetrievalConfig, base: usize) -> Self {
        let mut config = config;
        // Served distances carry convergence noise on the order of the
        // refine tolerance; a slack below it could prune a candidate
        // whose solved value would have landed just inside τ. (A
        // fixed-budget config has tolerance 0 and keeps its slack.)
        config.bound_slack = config.bound_slack.max(10.0 * config.sinkhorn.tolerance);
        let workers = if config.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            config.workers
        };
        let executor = match config.backend {
            Some(kind) => {
                ShardedExecutor::new(index.metric(), config.sinkhorn, kind, workers)
            }
            None => ShardedExecutor::auto(index.metric(), config.sinkhorn, workers),
        };
        let n = index.len();
        let globals: Vec<usize> = (base..base + n).collect();
        let local_of = globals.iter().enumerate().map(|(l, &g)| (g, l)).collect();
        Self {
            index,
            cascade: BoundCascade::new(),
            executor,
            config,
            queries: 0,
            globals,
            local_of,
            tombstones: vec![false; n],
            live: n,
            routing: None,
            router: None,
            #[cfg(any(test, debug_assertions))]
            poison_next_search: false,
        }
    }

    /// Enable the ANN routing tier: build a k-means router over the
    /// index's cached embedded-barycenter coordinates. Returns whether
    /// a router actually came up — `false` when the metric does not
    /// factor (no coordinate space), in which case searches keep the
    /// exact every-live-entry walk. Tombstoned slots are indexed but
    /// filtered at shortlist time; [`Self::compact`] rebuilds routing
    /// over the survivors.
    pub fn enable_routing(&mut self, config: RoutingConfig) -> bool {
        self.routing = Some(config);
        self.rebuild_router();
        self.router.is_some()
    }

    /// Whether an ANN router is active on this service.
    pub fn routing_active(&self) -> bool {
        self.router.is_some()
    }

    /// (Re)build the router from the current index slots, honoring the
    /// stored routing config. No-op when routing was never enabled.
    fn rebuild_router(&mut self) {
        let Some(cfg) = self.routing else {
            self.router = None;
            return;
        };
        let points: Option<Vec<Vec<F>>> = (0..self.index.len())
            .map(|e| self.index.entry_coordinates(e).map(|c| c.to_vec()))
            .collect();
        self.router = points.and_then(|pts| Router::build(cfg, &pts));
    }

    /// Arm the one-shot panic hook: the next search on this service
    /// panics mid-flight. Test-only plumbing for the sharded runtime's
    /// panic-containment contract.
    #[cfg(any(test, debug_assertions))]
    #[doc(hidden)]
    pub fn poison_next_search(&mut self) {
        self.poison_next_search = true;
    }

    /// The indexed corpus.
    pub fn index(&self) -> &CorpusIndex {
        &self.index
    }

    /// Index slots, including tombstoned ones awaiting compaction.
    pub fn len(&self) -> usize {
        self.globals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.globals.is_empty()
    }

    /// Live (searchable) entries.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Fraction of index slots currently tombstoned.
    pub fn tombstone_fraction(&self) -> f64 {
        if self.globals.is_empty() {
            return 0.0;
        }
        (self.globals.len() - self.live) as f64 / self.globals.len() as f64
    }

    /// Whether entry id `entry` is indexed and live.
    pub fn contains(&self, entry: usize) -> bool {
        self.local_of.get(&entry).is_some_and(|&l| !self.tombstones[l])
    }

    /// Append one histogram under the stable id `entry` (O(anchors·d):
    /// per-entry statistics are independent, no other entry is touched).
    /// The id must be fresh — reusing a live *or tombstoned* id would
    /// alias warm-cache keys and merge bookkeeping, so it panics.
    pub fn insert(&mut self, h: Histogram, entry: usize) -> Result<(), RetrievalError> {
        assert!(
            !self.local_of.contains_key(&entry),
            "entry id {entry} is already indexed"
        );
        let local = self.index.push(h)?;
        debug_assert_eq!(local, self.globals.len());
        self.globals.push(entry);
        self.local_of.insert(entry, local);
        self.tombstones.push(false);
        self.live += 1;
        // Incremental routing: the new slot joins its nearest centroid
        // (no rebuild — O(centroids·anchors)).
        if let Some(router) = self.router.as_mut() {
            if let Some(coords) = self.index.entry_coordinates(local) {
                router.insert(local, coords);
            }
        }
        Ok(())
    }

    /// Tombstone entry id `entry`: it stops appearing in any search
    /// immediately; its index slot is reclaimed by the next
    /// [`Self::compact`]. Returns whether a live entry was hit.
    pub fn tombstone(&mut self, entry: usize) -> bool {
        match self.local_of.get(&entry) {
            Some(&local) if !self.tombstones[local] => {
                self.tombstones[local] = true;
                self.live -= 1;
                true
            }
            _ => false,
        }
    }

    /// Rebuild the index over the live entries, dropping tombstoned
    /// slots. Stable ids are preserved, and so is the warm cache (its
    /// keys are the stable ids, not the renumbered slots). Returns
    /// whether anything was reclaimed; a fully-tombstoned service keeps
    /// its slots (an index cannot be empty) until an insert revives it.
    pub fn compact(&mut self) -> bool {
        if self.live == self.globals.len() || self.live == 0 {
            return false;
        }
        let mut survivors = Vec::with_capacity(self.live);
        let mut globals = Vec::with_capacity(self.live);
        for (local, &global) in self.globals.iter().enumerate() {
            if !self.tombstones[local] {
                survivors.push(self.index.entry(local).clone());
                globals.push(global);
            }
        }
        let mut index = CorpusIndex::from_histograms(
            self.index.metric(),
            survivors,
            self.index.anchors_requested(),
        )
        .expect("a non-empty survivor set of validated entries rebuilds");
        index.adopt_warm(&mut self.index);
        self.index = index;
        self.local_of = globals.iter().enumerate().map(|(l, &g)| (g, l)).collect();
        self.tombstones = vec![false; globals.len()];
        self.live = globals.len();
        self.globals = globals;
        // Routing state is slot-addressed: rebuild it over the
        // renumbered survivors.
        self.rebuild_router();
        true
    }

    /// The active configuration.
    pub fn config(&self) -> &RetrievalConfig {
        &self.config
    }

    /// The strategy the refine executor runs.
    pub fn backend_kind(&self) -> BackendKind {
        self.executor.kind()
    }

    /// Effective refine panel width.
    fn panel_width(&self) -> usize {
        if self.config.panel > 0 {
            self.config.panel
        } else {
            (self.executor.workers() * 4).max(8)
        }
    }

    /// Pruned top-k: identical results to [`Self::brute_force`] (same
    /// distances, same order modulo ties), at a fraction of the solves.
    /// Hits come back in ascending (distance, entry) order.
    pub fn top_k(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<(Vec<Hit>, RetrievalReport), RetrievalError> {
        if query.dim() != self.index.dim() {
            return Err(RetrievalError::QueryDimensionMismatch {
                got: query.dim(),
                want: self.index.dim(),
            });
        }
        self.queries += 1;
        #[cfg(any(test, debug_assertions))]
        if self.poison_next_search {
            self.poison_next_search = false;
            panic!("poisoned search (test hook)");
        }
        let k = k.min(self.live);
        let mut report = RetrievalReport::empty(self.live, k);
        if k == 0 {
            return Ok((Vec::new(), report));
        }

        let trace = ctx::active();
        let cascade_start = trace.as_ref().map(|t| t.sink.now_us());
        let prep = self.index.prepare(query);
        // Candidates are the live slots — or, with the ANN router
        // active, its tombstone-filtered shortlist. The exact walk is
        // byte-identical to the pre-routing path when no router is set.
        let live: Vec<usize> = match (&self.router, prep.coordinates()) {
            (Some(router), Some(coords)) => {
                report.routed = true;
                router.shortlist(coords, k, |s| self.tombstones[s])
            }
            _ => (0..self.index.len()).filter(|&e| !self.tombstones[e]).collect(),
        };
        let n = live.len();
        report.shortlist = n;
        let k = k.min(n);
        report.k = k;

        // Price every candidate and walk in ascending bound order
        // (positions index into `live`; ties break by stable id so the
        // walk is identical under any slot renumbering).
        let bounds: Vec<super::BoundValue> = live
            .iter()
            .map(|&e| self.cascade.evaluate(&self.index, &prep, query, e))
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            bounds[a]
                .value
                .total_cmp(&bounds[b].value)
                .then(self.globals[live[a]].cmp(&self.globals[live[b]]))
        });
        if let (Some(t), Some(start_us)) = (&trace, cascade_start) {
            let deepest = bounds
                .iter()
                .map(|b| match b.tier {
                    BoundTier::Mass => 0u8,
                    BoundTier::Centroid => 1,
                    BoundTier::Projection => 2,
                })
                .max()
                .unwrap_or(0);
            t.sink.record(Span {
                trace: t.trace,
                stage: Stage::Cascade,
                tenant: t.tenant,
                start_us,
                end_us: t.sink.now_us(),
                tid: 0,
                data: SpanData::Cascade { tier: deepest, priced: n },
            });
        }
        let refine_start = trace.as_ref().map(|t| t.sink.now_us());

        let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
        let panel_width = self.panel_width();

        // Seed: the k most promising candidates establish τ.
        let mut cursor = 0;
        while cursor < k {
            let take = (k - cursor).min(panel_width);
            let batch: Vec<usize> =
                order[cursor..cursor + take].iter().map(|&p| live[p]).collect();
            self.solve_into(query, &batch, &mut heap, k, &mut report);
            cursor += take;
        }
        let mut tau = kth_best(&heap, k);

        // Sweep: bounds ascend, τ descends — the first bound past
        // τ + slack prunes the entire tail.
        let mut batch = Vec::with_capacity(panel_width);
        while cursor < n {
            let slack = self.config.bound_slack * (1.0 + tau.abs());
            let p = order[cursor];
            if bounds[p].value > tau + slack {
                break;
            }
            batch.push(live[p]);
            cursor += 1;
            if batch.len() == panel_width || cursor == n {
                self.solve_into(query, &batch, &mut heap, k, &mut report);
                tau = kth_best(&heap, k);
                batch.clear();
            }
        }
        if !batch.is_empty() {
            self.solve_into(query, &batch, &mut heap, k, &mut report);
            tau = kth_best(&heap, k);
        }
        for &p in &order[cursor..] {
            report.pruned += 1;
            match bounds[p].tier {
                BoundTier::Mass => report.pruned_mass += 1,
                BoundTier::Centroid => report.pruned_centroid += 1,
                BoundTier::Projection => report.pruned_projection += 1,
            }
        }
        report.threshold = tau;
        if let (Some(t), Some(start_us)) = (&trace, refine_start) {
            t.sink.record(Span {
                trace: t.trace,
                stage: Stage::Refine,
                tenant: t.tenant,
                start_us,
                end_us: t.sink.now_us(),
                tid: 0,
                data: SpanData::Refine {
                    panels: report.panels,
                    warm_seeded: report.warm_seeded,
                    rescued: report.rescued,
                },
            });
        }

        let mut hits: Vec<Hit> = heap
            .into_sorted_vec()
            .into_iter()
            .map(|item| Hit {
                entry: item.entry,
                distance: item.distance,
                rescued: item.rescued,
            })
            .collect();
        hits.truncate(k);

        if self.config.probe_every > 0 && self.queries % self.config.probe_every == 0
        {
            let brute = self.brute_force(query, k)?;
            report.probe = Some(probe_outcome(&hits, &brute, self.config.bound_slack));
        }
        Ok((hits, report))
    }

    /// Brute-force top-k: every corpus entry solved (still in executor
    /// panels), no pruning. The oracle the pruned path is held to.
    pub fn brute_force(
        &mut self,
        query: &Histogram,
        k: usize,
    ) -> Result<Vec<Hit>, RetrievalError> {
        if query.dim() != self.index.dim() {
            return Err(RetrievalError::QueryDimensionMismatch {
                got: query.dim(),
                want: self.index.dim(),
            });
        }
        let live: Vec<usize> =
            (0..self.index.len()).filter(|&e| !self.tombstones[e]).collect();
        let n = live.len();
        let k = k.min(n);
        if k == 0 {
            return Ok(Vec::new());
        }
        let mut report = RetrievalReport::empty(n, k);
        let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
        let panel_width = self.panel_width();
        for batch in live.chunks(panel_width) {
            self.solve_into(query, batch, &mut heap, k, &mut report);
        }
        let mut hits: Vec<Hit> = heap
            .into_sorted_vec()
            .into_iter()
            .map(|item| Hit {
                entry: item.entry,
                distance: item.distance,
                rescued: item.rescued,
            })
            .collect();
        hits.truncate(k);
        Ok(hits)
    }

    /// Solve query vs the given corpus entries as one executor panel and
    /// fold the outcomes into the top-k heap.
    fn solve_into(
        &mut self,
        query: &Histogram,
        entries: &[usize],
        heap: &mut BinaryHeap<HeapItem>,
        k: usize,
        report: &mut RetrievalReport,
    ) {
        if entries.is_empty() {
            return;
        }
        let lambda = self.config.sinkhorn.lambda;
        // Warm keys are the *stable ids*, not the index slots: cached
        // scalings stay valid across compaction renumbering.
        let inits: Vec<ScalingInit> = if self.config.warm_start {
            entries
                .iter()
                .map(|&e| {
                    let global = self.globals[e];
                    self.index.warm_init(lambda, global).unwrap_or_default()
                })
                .collect()
        } else {
            vec![ScalingInit::Cold; entries.len()]
        };
        report.warm_seeded += inits.iter().filter(|i| !i.is_cold()).count();
        // The clone is the price of the SolverBackend panel signature
        // (`cs: &[Histogram]`, owned histograms, fixed since PR 1):
        // borrowing would ripple `&[&Histogram]` through every backend
        // and test. O(panel·d) copies per dispatch against O(iters·d²)
        // solve work per column keeps this far below the profile line.
        let cs: Vec<Histogram> =
            entries.iter().map(|&e| self.index.entry(e).clone()).collect();
        let rs: Vec<&Histogram> = entries.iter().map(|_| query).collect();
        if self.config.budget.is_unbounded() {
            let (outputs, _reports) =
                self.executor.solve_panel_paired_init(&rs, &cs, &inits);
            report.panels += 1;
            report.solved += outputs.len();
            for (&e, out) in entries.iter().zip(&outputs) {
                self.fold_output(e, out, heap, k, report, lambda);
            }
            return;
        }
        // Anytime refine: one cheap certified pass over the panel, then
        // the intervals decide who is worth a full solve. A candidate
        // that converged within the budget folds directly; one whose
        // whole interval clears τ is discarded; only the straddlers —
        // interval still containing τ — escalate. A traced query tags
        // every panel column with its id so the budgeted solve's
        // per-slice interval spans attribute back to it.
        let panel_trace = ctx::active().map(|t| PanelTrace {
            sink: Arc::clone(&t.sink),
            tenant: t.tenant,
            traces: vec![Some(t.trace); cs.len()],
        });
        let (outcomes, _reports) = self.executor.solve_panel_outcomes_traced(
            &rs,
            &cs,
            &inits,
            self.config.budget,
            panel_trace,
        );
        report.panels += 1;
        report.solved += outcomes.len();
        let mut pending: Vec<usize> = Vec::new();
        for (pos, (&e, o)) in entries.iter().zip(&outcomes).enumerate() {
            report.iterations += o.iterations;
            if !o.estimate.is_finite() {
                report.failed += 1;
                continue;
            }
            if o.converged {
                let rescued = o.stabilized
                    && self.executor.kind() != BackendKind::LogDomain;
                if rescued {
                    report.rescued += 1;
                }
                heap.push(HeapItem {
                    distance: o.estimate,
                    entry: self.globals[e],
                    rescued,
                });
                if heap.len() > k {
                    heap.pop();
                }
                continue;
            }
            pending.push(pos);
        }
        if pending.is_empty() {
            return;
        }
        let tau = kth_best(heap, k);
        let slack = self.config.bound_slack * (1.0 + tau.abs());
        let straddlers: Vec<usize> = pending
            .into_iter()
            .filter(|&pos| {
                if outcomes[pos].interval.lo > tau + slack {
                    report.pruned_interval += 1;
                    false
                } else {
                    true
                }
            })
            .collect();
        if straddlers.is_empty() {
            return;
        }
        report.refined += straddlers.len();
        let sub_rs: Vec<&Histogram> = straddlers.iter().map(|_| query).collect();
        let sub_cs: Vec<Histogram> =
            straddlers.iter().map(|&p| cs[p].clone()).collect();
        let sub_inits: Vec<ScalingInit> =
            straddlers.iter().map(|&p| inits[p].clone()).collect();
        let (outputs, _reports) =
            self.executor.solve_panel_paired_init(&sub_rs, &sub_cs, &sub_inits);
        report.panels += 1;
        for (&p, out) in straddlers.iter().zip(&outputs) {
            self.fold_output(entries[p], out, heap, k, report, lambda);
        }
    }

    fn fold_output(
        &mut self,
        entry: usize,
        out: &SinkhornOutput,
        heap: &mut BinaryHeap<HeapItem>,
        k: usize,
        report: &mut RetrievalReport,
        lambda: F,
    ) {
        report.iterations += out.stats.iterations;
        // `stabilized` is set by *every* log-domain solve; it means
        // "rescued" only when the class's own backend is not log-domain
        // (a log-domain-pinned or underflow-routed class would otherwise
        // report a meaningless 100% rescue rate).
        let rescued = out.stats.stabilized
            && self.executor.kind() != BackendKind::LogDomain;
        if rescued {
            report.rescued += 1;
        }
        let global = self.globals[entry];
        if self.config.warm_start {
            self.index.warm_deposit(lambda, global, out);
        }
        if !out.value.is_finite() {
            report.failed += 1;
            return;
        }
        heap.push(HeapItem { distance: out.value, entry: global, rescued });
        if heap.len() > k {
            heap.pop();
        }
    }
}

/// Tie-aware probe scoring, mirroring the exactness contract
/// ("identical modulo ties", see [`super::topk_equivalent`]): a
/// pruned-only hit also counts as confirmed when it ties — within the
/// same slack that guards pruning — with a *brute-force-only* hit, so a
/// k-th/(k+1)-th tie flipping between the two walks is not flagged as a
/// recall miss, while a genuinely wrong entry (whose distance merely
/// resembles some shared neighbor's) still is. Shared by the standalone
/// service, the sharded runtime's merged-view probes, and the routing
/// bench's recall hard-assert.
pub fn probe_outcome(hits: &[Hit], brute: &[Hit], slack: F) -> ProbeOutcome {
    let brute_set: std::collections::HashSet<usize> =
        brute.iter().map(|h| h.entry).collect();
    let hit_set: std::collections::HashSet<usize> =
        hits.iter().map(|h| h.entry).collect();
    let matched = hits
        .iter()
        .filter(|h| {
            brute_set.contains(&h.entry)
                || brute.iter().any(|b| {
                    !hit_set.contains(&b.entry)
                        && (b.distance - h.distance).abs()
                            <= slack * (1.0 + b.distance.abs())
                })
        })
        .count();
    ProbeOutcome { matched, k: hits.len() }
}

/// The current k-th best served distance (∞ until the heap fills).
fn kth_best(heap: &BinaryHeap<HeapItem>, k: usize) -> F {
    if heap.len() < k {
        F::INFINITY
    } else {
        heap.peek().map(|item| item.distance).unwrap_or(F::INFINITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::simplex::seeded_rng;

    fn service(d: usize, n: usize, seed: u64, lambda: F) -> RetrievalService {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        let mut config = RetrievalConfig::serving(lambda);
        config.workers = 2;
        RetrievalService::new(index, config)
    }

    #[test]
    fn top_k_matches_brute_force_on_a_small_corpus() {
        let mut svc = service(10, 40, 0, 9.0);
        let mut rng = seeded_rng(100);
        let q = Histogram::sample_uniform(10, &mut rng);
        let brute = svc.brute_force(&q, 5).unwrap();
        let (got, report) = svc.top_k(&q, 5).unwrap();
        assert_eq!(got.len(), 5);
        assert_eq!(report.solved + report.pruned, 40);
        for (a, b) in got.iter().zip(&brute) {
            assert_eq!(a.entry, b.entry);
            assert!((a.distance - b.distance).abs() < 1e-9 * (1.0 + b.distance));
        }
        // Ascending canonical order.
        for w in got.windows(2) {
            assert!(w[0].distance <= w[1].distance + 1e-15);
        }
    }

    #[test]
    fn k_edge_cases() {
        let mut svc = service(8, 12, 1, 9.0);
        let mut rng = seeded_rng(101);
        let q = Histogram::sample_uniform(8, &mut rng);
        let (empty, report) = svc.top_k(&q, 0).unwrap();
        assert!(empty.is_empty());
        assert_eq!(report.solved, 0);
        // k beyond the corpus clamps and solves everything.
        let (all, report) = svc.top_k(&q, 50).unwrap();
        assert_eq!(all.len(), 12);
        assert_eq!(report.k, 12);
        assert_eq!(report.pruned, 0);
        assert_eq!(report.solved, 12);
    }

    #[test]
    fn dimension_mismatch_is_an_error() {
        let mut svc = service(8, 4, 2, 9.0);
        let q = Histogram::uniform(5);
        assert!(matches!(
            svc.top_k(&q, 2),
            Err(RetrievalError::QueryDimensionMismatch { got: 5, want: 8 })
        ));
        assert!(svc.brute_force(&q, 2).is_err());
    }

    #[test]
    fn warm_cache_seeds_repeat_queries() {
        let mut svc = service(10, 16, 3, 9.0);
        let mut rng = seeded_rng(103);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (_, cold) = svc.top_k(&q, 4).unwrap();
        assert_eq!(cold.warm_seeded, 0);
        let (hits_cold, _) = svc.top_k(&q, 4).unwrap();
        let (_, warm) = svc.top_k(&q, 4).unwrap();
        assert!(warm.warm_seeded > 0, "repeat query must hit the entry cache");
        assert!(warm.iterations <= cold.iterations);
        // Warm starts never change the answers.
        let (hits_warm, _) = svc.top_k(&q, 4).unwrap();
        for (a, b) in hits_warm.iter().zip(&hits_cold) {
            assert_eq!(a.entry, b.entry);
            assert!((a.distance - b.distance).abs() < 1e-7 * (1.0 + b.distance));
        }
    }

    #[test]
    fn squared_costs_stay_exact_without_the_projection_tier() {
        // Squared-Euclidean ground costs disable every projection anchor
        // (reverse triangle fails); pruning must stay exact on the
        // surviving mass + centroid tiers.
        use crate::metric::GridMetric;
        let m = GridMetric::new(3, 3).squared_cost_matrix();
        let mut rng = seeded_rng(50);
        let entries: Vec<Histogram> =
            (0..30).map(|_| Histogram::sample_uniform(9, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        assert!(index.anchors().is_empty());
        let mut config = RetrievalConfig::serving(5.0);
        config.workers = 2;
        config.sinkhorn.tolerance = 1e-12;
        config.sinkhorn.max_iterations = 200_000;
        let mut svc = RetrievalService::new(index, config);
        let q = Histogram::sample_uniform(9, &mut rng);
        let brute = svc.brute_force(&q, 5).unwrap();
        let (got, report) = svc.top_k(&q, 5).unwrap();
        assert_eq!(report.pruned_projection, 0, "tier is disabled");
        for (a, b) in got.iter().zip(&brute) {
            assert_eq!(a.entry, b.entry);
            assert!((a.distance - b.distance).abs() < 1e-9 * (1.0 + b.distance));
        }
    }

    #[test]
    fn slack_floor_tracks_the_refine_tolerance() {
        let mut rng = seeded_rng(51);
        let m = crate::metric::RandomMetric::new(8).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..4).map(|_| Histogram::sample_uniform(8, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries, 2).unwrap();
        let mut config = RetrievalConfig::serving(9.0);
        config.sinkhorn.tolerance = 1e-6; // coarse serving tolerance
        config.workers = 1;
        let svc = RetrievalService::new(index, config);
        assert!(
            svc.config().bound_slack >= 1e-5,
            "slack {} must be floored at 10x the tolerance",
            svc.config().bound_slack
        );
    }

    #[test]
    fn mutation_cycle_keeps_search_exact_and_ids_stable() {
        let mut svc = service(10, 20, 7, 9.0);
        let mut rng = seeded_rng(107);
        let q = Histogram::sample_uniform(10, &mut rng);

        // Insert a duplicate of the query under a fresh id: it must be
        // searchable immediately (per-entry stats are independent).
        svc.insert(q.clone(), 20).unwrap();
        assert_eq!((svc.len(), svc.live()), (21, 21));
        assert!(svc.contains(20));
        let (hits, _) = svc.top_k(&q, 3).unwrap();
        assert!(
            hits.iter().any(|h| h.entry == 20),
            "an exact duplicate of the query must reach the top-3: {hits:?}"
        );

        // Tombstone it: gone from the very next search, id never reused.
        assert!(svc.tombstone(20));
        assert!(!svc.tombstone(20), "double tombstone is a no-op");
        assert!(!svc.contains(20));
        assert_eq!((svc.len(), svc.live()), (21, 20));
        assert!((svc.tombstone_fraction() - 1.0 / 21.0).abs() < 1e-12);
        let (hits, report) = svc.top_k(&q, 3).unwrap();
        assert!(hits.iter().all(|h| h.entry != 20));
        assert_eq!(report.corpus, 20, "tombstoned slots are not candidates");

        // Tombstone a live original entry too, then compact: results
        // must be identical before and after (ids are stable, only the
        // internal slots renumber), and the brute oracle agrees.
        assert!(svc.tombstone(3));
        let (before, _) = svc.top_k(&q, 5).unwrap();
        assert!(svc.compact());
        assert!(!svc.compact(), "nothing left to reclaim");
        assert_eq!((svc.len(), svc.live()), (19, 19));
        let (after, _) = svc.top_k(&q, 5).unwrap();
        if let Err(v) = super::super::topk_equivalent(&after, &before, 1e-7) {
            panic!("compaction changed the answer: {v}");
        }
        let brute = svc.brute_force(&q, 5).unwrap();
        if let Err(v) = super::super::topk_equivalent(&after, &brute, 1e-7) {
            panic!("post-compaction pruning diverged from brute force: {v}");
        }
        assert!(brute.iter().all(|h| h.entry != 3 && h.entry != 20));

        // The warm cache survives compaction: ids, not slots, key it.
        let (_, warm) = svc.top_k(&q, 5).unwrap();
        assert!(warm.warm_seeded > 0, "repeat query must hit the entry cache");

        // Tombstoning an unknown id is a no-op; a duplicate insert id
        // panics (defended in ShardedCorpus by monotone id assignment).
        assert!(!svc.tombstone(999));
    }

    #[test]
    fn fully_tombstoned_service_serves_empty_results() {
        let mut svc = service(8, 3, 8, 9.0);
        for e in 0..3 {
            assert!(svc.tombstone(e));
        }
        assert_eq!(svc.live(), 0);
        let mut rng = seeded_rng(108);
        let q = Histogram::sample_uniform(8, &mut rng);
        let (hits, report) = svc.top_k(&q, 2).unwrap();
        assert!(hits.is_empty());
        assert_eq!((report.corpus, report.k, report.solved), (0, 0, 0));
        assert!(svc.brute_force(&q, 2).unwrap().is_empty());
        // Compacting to empty is refused (an index cannot be empty);
        // an insert under a fresh id revives the shard (tombstoned ids
        // stay reserved — reusing one would alias warm-cache keys).
        assert!(!svc.compact());
        svc.insert(q.clone(), 7).unwrap();
        let (hits, _) = svc.top_k(&q, 2).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].entry, 7);
    }

    #[test]
    fn with_base_offsets_every_reported_id() {
        let mut rng = seeded_rng(109);
        let m = crate::metric::RandomMetric::new(8).sample(&mut rng);
        let entries: Vec<Histogram> =
            (0..6).map(|_| Histogram::sample_uniform(8, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries, 2).unwrap();
        let mut config = RetrievalConfig::serving(9.0);
        config.workers = 1;
        let mut svc = RetrievalService::with_base(index, config, 100);
        let q = Histogram::sample_uniform(8, &mut rng);
        let (hits, _) = svc.top_k(&q, 6).unwrap();
        assert_eq!(hits.len(), 6);
        let mut ids: Vec<usize> = hits.iter().map(|h| h.entry).collect();
        ids.sort_unstable();
        assert_eq!(ids, (100..106).collect::<Vec<_>>());
        assert!(svc.contains(100) && !svc.contains(0));
        assert!(svc.tombstone(101));
    }

    #[test]
    fn recall_probe_confirms_pruning() {
        let mut svc = service(10, 24, 4, 9.0);
        svc.config.probe_every = 1;
        let mut rng = seeded_rng(104);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (_, report) = svc.top_k(&q, 3).unwrap();
        let probe = report.probe.expect("probe_every=1 must probe");
        assert_eq!(probe.matched, probe.k, "pruned top-k must equal brute force");
    }

    #[test]
    fn generous_budget_matches_unbounded_top_k() {
        // A budget large enough for every solve to converge must leave
        // the served top-k identical (modulo ties) to the exact pipeline
        // — the anytime cascade only ever prunes on *certified* bounds.
        let mut exact_svc = service(10, 32, 5, 9.0);
        let mut rng = seeded_rng(105);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (want, _) = exact_svc.top_k(&q, 5).unwrap();

        let mut budgeted = service(10, 32, 5, 9.0);
        budgeted.config.budget = SolveBudget::Iterations(10_000);
        let (got, report) = budgeted.top_k(&q, 5).unwrap();
        if let Err(v) = super::super::topk_equivalent(&got, &want, 1e-7) {
            panic!("generous budget changed the answer: {v}");
        }
        // Everything converged under the generous cap, so the interval
        // filter had no straddlers to escalate.
        assert_eq!(report.refined, 0, "no refinement under a generous budget");
    }

    #[test]
    fn tight_budget_prunes_on_intervals_and_stays_well_formed() {
        let mut svc = service(10, 32, 6, 9.0);
        svc.config.budget = SolveBudget::Iterations(8);
        let mut rng = seeded_rng(106);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (hits, report) = svc.top_k(&q, 4).unwrap();
        assert_eq!(hits.len(), 4);
        for h in &hits {
            assert!(h.distance.is_finite() && h.distance >= 0.0);
        }
        for w in hits.windows(2) {
            assert!(w[0].distance <= w[1].distance + 1e-15);
        }
        // Every candidate is accounted for exactly once across the
        // cascade tiers and the interval filter.
        assert!(
            report.pruned + report.solved == report.corpus,
            "candidate accounting broke: {report:?}"
        );
        // Interval-pruned candidates never went through a full refine.
        assert!(report.refined + report.pruned_interval <= report.corpus);
        // The unbounded oracle's top-k distances lower-bound nothing
        // here — but each served hit must at least match the brute-force
        // entry set when re-solved exactly. (Smoke-level: the heap never
        // serves an interval-pruned candidate.)
        let brute = svc.brute_force(&q, 4).unwrap();
        let brute_worst = brute.last().unwrap().distance;
        for h in &hits {
            assert!(
                h.distance <= brute_worst + 0.5 * (1.0 + brute_worst),
                "budgeted hit wildly above the exact top-k band: {} vs {brute_worst}",
                h.distance
            );
        }
    }

    /// A clustered service with an active ANN router.
    fn routed_service(seed: u64) -> (RetrievalService, Vec<Histogram>) {
        use crate::data::ClusteredCorpus;
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(12).sample(&mut rng);
        let spec = ClusteredCorpus::new(12, 4, 16, 0.1);
        let (entries, protos) = spec.generate(&mut rng);
        let index = CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        let mut config = RetrievalConfig::serving(9.0);
        config.workers = 2;
        let mut svc = RetrievalService::new(index, config);
        let enabled = svc.enable_routing(RoutingConfig {
            centroids: 8,
            probes: 2,
            min_shortlist: 16,
            iterations: 8,
        });
        assert!(enabled, "a factoring random metric must yield a coordinate space");
        (svc, protos)
    }

    #[test]
    fn routing_shortlists_sublinearly_with_high_recall() {
        let (mut svc, protos) = routed_service(60);
        assert!(svc.routing_active());
        let q = protos[0].clone();
        let brute = svc.brute_force(&q, 5).unwrap();
        let (hits, report) = svc.top_k(&q, 5).unwrap();
        assert!(report.routed, "router must own candidate generation");
        assert_eq!(report.corpus, 64);
        assert!(
            report.shortlist < report.corpus,
            "shortlist {} must be sublinear in the corpus",
            report.shortlist
        );
        assert_eq!(
            report.solved + report.pruned,
            report.shortlist,
            "every shortlisted candidate is priced exactly once: {report:?}"
        );
        assert!(report.shortlist_fraction() < 1.0);
        let probe = probe_outcome(&hits, &brute, svc.config().bound_slack);
        assert!(
            probe.matched + 1 >= probe.k,
            "routed recall collapsed: {} of {}",
            probe.matched,
            probe.k
        );
    }

    #[test]
    fn routing_rides_the_mutation_lifecycle() {
        let (mut svc, protos) = routed_service(61);
        let q = protos[1].clone();
        // An inserted duplicate of the query routes to the query's own
        // nearest centroid, so it is shortlisted immediately.
        svc.insert(q.clone(), 64).unwrap();
        let (hits, report) = svc.top_k(&q, 3).unwrap();
        assert!(report.routed);
        assert!(
            hits.iter().any(|h| h.entry == 64),
            "inserted duplicate must be routed into the shortlist: {hits:?}"
        );
        // Tombstones are honored at shortlist time.
        assert!(svc.tombstone(64));
        let (hits, _) = svc.top_k(&q, 3).unwrap();
        assert!(hits.iter().all(|h| h.entry != 64));
        // Compaction rebuilds the router over the renumbered survivors.
        assert!(svc.tombstone(0));
        assert!(svc.compact());
        assert!(svc.routing_active(), "compaction must rebuild the router");
        let (hits, report) = svc.top_k(&q, 3).unwrap();
        assert!(report.routed);
        assert_eq!(report.corpus, 63);
        assert!(hits.iter().all(|h| h.entry != 0 && h.entry != 64));
    }

    #[test]
    fn disabled_routing_reports_full_shortlist() {
        let mut svc = service(10, 20, 9, 9.0);
        let mut rng = seeded_rng(110);
        let q = Histogram::sample_uniform(10, &mut rng);
        let (_, report) = svc.top_k(&q, 4).unwrap();
        assert!(!report.routed);
        assert_eq!(report.shortlist, report.corpus);
        assert_eq!(report.shortlist_fraction(), 1.0);
    }
}
