//! The corpus side of retrieval: ingestion, validation, normalization
//! and per-entry precomputation.
//!
//! A [`CorpusIndex`] binds a histogram corpus to one ground metric and
//! precomputes everything the [`super::BoundCascade`] needs to price a
//! candidate in O(d) at query time:
//!
//! * **anchor axes** — a small farthest-point-sampled anchor set; for
//!   each anchor a the bins are projected to x_i = m_{a,i} (reverse
//!   triangle inequality: |x_i − x_j| ≤ m_ij), the projection sorted
//!   once, and every entry's sorted CDF cached, so the 1-D
//!   quantile-transport bound of [`crate::ot::onedim`] costs one
//!   CDF-difference sweep per anchor;
//! * **centroid coordinates** — when the metric is of negative type
//!   (plain and squared Euclidean distance matrices both are), the
//!   [`crate::sinkhorn::IndependenceKernel`] embedding is factored once
//!   and each entry's embedded barycenter Lᵀc cached, so the Jensen
//!   centroid bound costs one d-vector difference;
//! * **warm scalings** — a [`WarmStartStore`] keyed *by corpus entry*:
//!   the refine stage deposits every converged scaling pair back, so a
//!   later query against the same entry starts from the previous fixed
//!   point (warm starts change the path, never the fixed point — the
//!   refine stage runs convergence-checked, so served values are
//!   unaffected).
//!
//! Memory: per entry, `anchors`·(d−1) CDF values plus (when the
//! embedding factors) d centroid coordinates — ~5·d·8 bytes at the
//! default 4 anchors.
//!
//! Every per-entry statistic is a function of the *metric* and that one
//! entry alone (the anchor axes and the embedding factorization are
//! metric-only), which is what makes the index incrementally mutable:
//! [`CorpusIndex::push`] appends one entry in O(anchors·d) without
//! touching any other entry, and the sharded runtime
//! ([`super::ShardedCorpus`]) partitions a corpus into many independent
//! indexes whose per-shard results merge associatively.

use super::RetrievalError;
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::sinkhorn::{
    IndependenceKernel, PreparedHistogram, ScalingInit, SinkhornOutput, WarmCounters,
    WarmKey, WarmStartStore,
};
use crate::F;

/// One 1-D projection axis: an anchor bin, the sort permutation of the
/// projected positions m_{a,·}, and the gaps between consecutive sorted
/// positions (the weights of the CDF-difference sum).
struct AnchorAxis {
    /// Anchor bin index (kept for reporting).
    anchor: usize,
    /// Bin order sorted by projected position.
    perm: Vec<usize>,
    /// x_{(k+1)} − x_{(k)} for the sorted positions, length d − 1.
    gaps: Vec<F>,
}

/// Per-entry centroid-bound state: the factored embedding plus every
/// entry's prepared (barycenter) coordinates.
struct CentroidSpace {
    kernel: IndependenceKernel,
    prepared: Vec<PreparedHistogram>,
}

/// Query-side precomputation: the query's sorted CDF per anchor axis and
/// (when the embedding exists) its prepared coordinates. Built once per
/// query by [`CorpusIndex::prepare`], then shared across every corpus
/// candidate the cascade prices.
pub struct QueryPrep {
    /// Per anchor: prefix sums of the permuted query, length d − 1.
    cdfs: Vec<Vec<F>>,
    /// Prepared embedding coordinates (None when the metric did not
    /// factor as negative type).
    prepared: Option<PreparedHistogram>,
}

impl QueryPrep {
    /// The query's embedded-barycenter coordinates Lᵀq, when the metric
    /// factors — what the ANN router ranks centroids against.
    pub(crate) fn coordinates(&self) -> Option<&[F]> {
        self.prepared.as_ref().map(|p| p.coordinates())
    }
}

/// A validated, normalized histogram corpus bound to one ground metric,
/// with the per-entry statistics the bound cascade prices candidates
/// from and a per-entry warm-start cache for the refine stage.
pub struct CorpusIndex {
    metric: CostMatrix,
    entries: Vec<Histogram>,
    /// min_{i≠j} m_ij — the unit cost of the trivial mass/TV bound.
    min_off_diagonal: F,
    axes: Vec<AnchorAxis>,
    /// Per anchor: flattened (entries × (d−1)) sorted-CDF table.
    cdfs: Vec<Vec<F>>,
    centroid: Option<CentroidSpace>,
    warm: WarmStartStore,
    /// The anchor budget the index was built with (compaction rebuilds
    /// reuse it; the *surviving* anchor count after the admissibility
    /// filter may be smaller).
    anchors_requested: usize,
}

impl CorpusIndex {
    /// Default number of 1-D projection anchors.
    pub const DEFAULT_ANCHORS: usize = 4;

    /// Build an index over already-validated histograms (each histogram
    /// is normalized by construction). `anchors` caps the projection
    /// anchor set (clamped to [1, d]; [`Self::DEFAULT_ANCHORS`] is the
    /// usual choice).
    pub fn from_histograms(
        metric: &CostMatrix,
        entries: Vec<Histogram>,
        anchors: usize,
    ) -> Result<Self, RetrievalError> {
        if entries.is_empty() {
            return Err(RetrievalError::EmptyCorpus);
        }
        let d = metric.dim();
        for (i, h) in entries.iter().enumerate() {
            if h.dim() != d {
                return Err(RetrievalError::DimensionMismatch {
                    entry: i,
                    got: h.dim(),
                    want: d,
                });
            }
        }
        let min_off_diagonal = min_off_diagonal(metric);
        let axes = select_axes(metric, anchors.clamp(1, d));
        let mut cdfs = Vec::with_capacity(axes.len());
        for axis in &axes {
            let mut table = Vec::with_capacity(entries.len() * d.saturating_sub(1));
            for h in &entries {
                push_sorted_cdf(&mut table, h.values(), &axis.perm);
            }
            cdfs.push(table);
        }
        let centroid = IndependenceKernel::new(metric).ok().map(|kernel| {
            let prepared = entries.iter().map(|h| kernel.prepare(h)).collect();
            CentroidSpace { kernel, prepared }
        });
        let capacity = entries.len();
        Ok(Self {
            metric: metric.clone(),
            entries,
            min_off_diagonal,
            axes,
            cdfs,
            centroid,
            warm: WarmStartStore::new(capacity),
            anchors_requested: anchors,
        })
    }

    /// Append one already-validated histogram, computing its per-entry
    /// statistics in O(anchors·d): a CDF row against each fixed anchor
    /// axis plus (when the metric embeds) its prepared barycenter
    /// coordinates. The axes and the embedding are functions of the
    /// *metric* alone, so they stay valid for every appended entry and
    /// no existing entry is touched. Returns the new entry's slot.
    pub fn push(&mut self, h: Histogram) -> Result<usize, RetrievalError> {
        let d = self.dim();
        if h.dim() != d {
            return Err(RetrievalError::DimensionMismatch {
                entry: self.entries.len(),
                got: h.dim(),
                want: d,
            });
        }
        for (axis, table) in self.axes.iter().zip(&mut self.cdfs) {
            push_sorted_cdf(table, h.values(), &axis.perm);
        }
        if let Some(space) = self.centroid.as_mut() {
            let prepared = space.kernel.prepare(&h);
            space.prepared.push(prepared);
        }
        self.entries.push(h);
        // The warm cache tracks the corpus as it grows (resize only
        // evicts on shrink), so append-only corpora don't thrash a
        // build-time-sized LRU forever.
        self.warm.resize(self.entries.len());
        Ok(self.entries.len() - 1)
    }

    /// The anchor budget this index was built with (not the surviving
    /// anchor count — see [`Self::anchors`]).
    pub fn anchors_requested(&self) -> usize {
        self.anchors_requested
    }

    /// Take over `from`'s warm cache (used by shard compaction: the
    /// cache is keyed by caller-stable entry ids, so its contents stay
    /// valid across an index rebuild; cached scalings of dropped
    /// entries simply age out of the LRU). The adopted store is resized
    /// to this index's entry count, so a rebuilt shard's cache capacity
    /// tracks its live size — this is what makes the
    /// [`Self::warm_deposit`] cache-pressure note temporary.
    pub(crate) fn adopt_warm(&mut self, from: &mut CorpusIndex) {
        std::mem::swap(&mut self.warm, &mut from.warm);
        self.warm.resize(self.entries.len());
    }

    /// Ingest raw non-negative weight rows: each row is validated and
    /// normalized onto the simplex ([`Histogram::from_weights`]) before
    /// indexing.
    pub fn from_weights(
        metric: &CostMatrix,
        rows: &[Vec<F>],
        anchors: usize,
    ) -> Result<Self, RetrievalError> {
        let entries = rows
            .iter()
            .enumerate()
            .map(|(i, row)| {
                Histogram::from_weights(row)
                    .map_err(|source| RetrievalError::BadEntry { entry: i, source })
            })
            .collect::<Result<Vec<_>, _>>()?;
        Self::from_histograms(metric, entries, anchors)
    }

    /// Corpus size.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Histogram dimension d shared by the metric and every entry.
    pub fn dim(&self) -> usize {
        self.metric.dim()
    }

    /// The bound ground metric.
    pub fn metric(&self) -> &CostMatrix {
        &self.metric
    }

    /// Corpus entry i.
    pub fn entry(&self, i: usize) -> &Histogram {
        &self.entries[i]
    }

    /// All corpus entries, in ingestion order.
    pub fn entries(&self) -> &[Histogram] {
        &self.entries
    }

    /// The selected projection anchor bins. Empty when the ground cost
    /// violates the triangle inequality (e.g. squared-Euclidean
    /// matrices): the projection bound would be inadmissible there, so
    /// the tier is disabled rather than allowed to prune true
    /// neighbors.
    pub fn anchors(&self) -> Vec<usize> {
        self.axes.iter().map(|a| a.anchor).collect()
    }

    /// Whether the centroid (negative-type embedding) bound is available
    /// for this metric.
    pub fn has_centroid_space(&self) -> bool {
        self.centroid.is_some()
    }

    /// The cached embedded-barycenter coordinates Lᵀc of entry slot
    /// `entry` — the feature space the ANN router clusters in. `None`
    /// when the metric did not factor (no centroid space).
    pub(crate) fn entry_coordinates(&self, entry: usize) -> Option<&[F]> {
        self.centroid.as_ref().map(|space| space.prepared[entry].coordinates())
    }

    /// Precompute the query-side statistics shared across all candidate
    /// bound evaluations.
    pub fn prepare(&self, query: &Histogram) -> QueryPrep {
        assert_eq!(query.dim(), self.dim(), "query dimension mismatch");
        let cdfs = self
            .axes
            .iter()
            .map(|axis| {
                let mut cdf = Vec::with_capacity(self.dim().saturating_sub(1));
                push_sorted_cdf(&mut cdf, query.values(), &axis.perm);
                cdf
            })
            .collect();
        let prepared =
            self.centroid.as_ref().map(|space| space.kernel.prepare(query));
        QueryPrep { cdfs, prepared }
    }

    /// Trivial mass bound: moving the TV discrepancy anywhere costs at
    /// least min_{i≠j} m_ij per unit mass, so
    /// d_M ≥ ½‖q − c‖₁ · min_off_diagonal.
    pub fn mass_bound(&self, query: &Histogram, entry: usize) -> F {
        if self.min_off_diagonal <= 0.0 {
            return 0.0;
        }
        let tv: F = query
            .values()
            .iter()
            .zip(self.entries[entry].values())
            .map(|(a, b)| (a - b).abs())
            .sum();
        0.5 * tv * self.min_off_diagonal
    }

    /// Centroid bound ‖Lᵀq − Lᵀc‖² − 2·jitter (see
    /// [`IndependenceKernel::centroid_gap`]); `None` when the metric did
    /// not factor as negative type.
    pub fn centroid_bound(&self, prep: &QueryPrep, entry: usize) -> Option<F> {
        let space = self.centroid.as_ref()?;
        let q = prep.prepared.as_ref()?;
        Some(space.kernel.centroid_gap(q, &space.prepared[entry]))
    }

    /// 1-D quantile-transport projection bound: the max over anchor axes
    /// of Σ_k |Q_k − C_k|·gap_k against the cached sorted CDFs (the
    /// closed form of [`crate::ot::onedim::projection_lower_bound`],
    /// amortized through the index precomputation).
    pub fn projection_bound(&self, prep: &QueryPrep, entry: usize) -> F {
        let width = self.dim().saturating_sub(1);
        let mut best = 0.0;
        for (axis_idx, axis) in self.axes.iter().enumerate() {
            let q = &prep.cdfs[axis_idx];
            let c = &self.cdfs[axis_idx][entry * width..(entry + 1) * width];
            let mut acc = 0.0;
            for k in 0..width {
                acc += (q[k] - c[k]).abs() * axis.gaps[k];
            }
            best = F::max(best, acc);
        }
        best
    }

    /// Fetch the cached converged scalings for cache key `entry` at the
    /// given λ. The key is any caller-stable id — a standalone service
    /// passes the entry slot, the sharded path passes the corpus-global
    /// entry id so cached scalings survive compaction (which renumbers
    /// slots but not ids). A previous query's fixed point against the
    /// same entry seeds the next solve.
    pub fn warm_init(&mut self, lambda: F, entry: usize) -> Option<ScalingInit> {
        self.warm.get(&entry_key(lambda, entry))
    }

    /// Deposit a refine-stage solve back into the per-entry cache (only
    /// converged, finite solves are kept). `entry` follows the same
    /// stable-id contract as [`Self::warm_init`]. The LRU capacity is
    /// fixed at the build-time corpus size, so a heavily grown shard
    /// sees cache pressure until its next compaction rebuild.
    pub fn warm_deposit(&mut self, lambda: F, entry: usize, out: &SinkhornOutput) {
        if out.stats.converged && out.value.is_finite() {
            self.warm.insert(entry_key(lambda, entry), ScalingInit::from_output(out));
        }
    }

    /// Cumulative hit/miss/insert/evict counters of the per-entry warm
    /// cache.
    pub fn warm_counters(&self) -> WarmCounters {
        self.warm.counters()
    }
}

/// Warm-cache key for one corpus entry at one λ (the [`WarmKey`]
/// fingerprint slot carries the entry id — the corpus is the namespace,
/// so the usual query-pair fingerprint is deliberately not used).
fn entry_key(lambda: F, entry: usize) -> WarmKey {
    WarmKey { metric: 0, lambda_bits: lambda.to_bits(), fingerprint: entry as u64 }
}

/// min_{i≠j} m_ij (0 for d = 1).
fn min_off_diagonal(metric: &CostMatrix) -> F {
    let d = metric.dim();
    let mut min = F::INFINITY;
    for i in 0..d {
        for j in 0..d {
            if i != j {
                min = F::min(min, metric.get(i, j));
            }
        }
    }
    if min.is_finite() {
        min
    } else {
        0.0
    }
}

/// Anchor admissibility for the projection bound: the bound relies on
/// the *reverse triangle inequality* |m_{a,i} − m_{a,j}| ≤ m_ij, which
/// holds for genuine metrics but fails for non-metric ground costs the
/// crate also serves (squared-Euclidean matrices, footnote 1 of the
/// paper, violate it: on a line at 0,1,2 the anchor-0 projection spreads
/// bins 1 and 2 by 3 > m_12 = 1). An inadmissible anchor would inflate
/// the "lower" bound past d_M and silently prune true neighbors, so
/// such anchors are dropped at build time — the projection tier degrades
/// to the surviving anchors (or to nothing), exactly like the centroid
/// tier is guarded by factorization success. The tiny relative tolerance
/// admits float-noise-level violations, which the search's
/// `bound_slack` already absorbs.
fn anchor_admissible(metric: &CostMatrix, anchor: usize) -> bool {
    let d = metric.dim();
    let row = metric.row(anchor);
    for i in 0..d {
        for j in (i + 1)..d {
            let mij = metric.get(i, j);
            if (row[i] - row[j]).abs() > mij + 1e-12 * (1.0 + mij) {
                return false;
            }
        }
    }
    true
}

/// Farthest-point anchor selection: start from the most peripheral bin
/// (largest metric row sum), then greedily add the bin farthest from the
/// chosen set. Stops early when every remaining bin is metrically
/// indistinct from the chosen set (duplicate anchors add no information).
/// Anchors failing the [`anchor_admissible`] reverse-triangle check are
/// discarded.
fn select_axes(metric: &CostMatrix, anchors: usize) -> Vec<AnchorAxis> {
    let d = metric.dim();
    let mut chosen: Vec<usize> = Vec::with_capacity(anchors);
    let first = (0..d)
        .max_by(|&a, &b| {
            let sa: F = metric.row(a).iter().sum();
            let sb: F = metric.row(b).iter().sum();
            sa.total_cmp(&sb).then(b.cmp(&a))
        })
        .unwrap_or(0);
    chosen.push(first);
    while chosen.len() < anchors {
        let (next, gap) = (0..d)
            .map(|i| {
                let dist = chosen
                    .iter()
                    .map(|&a| metric.get(a, i))
                    .fold(F::INFINITY, F::min);
                (i, dist)
            })
            .max_by(|a, b| a.1.total_cmp(&b.1).then(b.0.cmp(&a.0)))
            .unwrap_or((0, 0.0));
        if gap <= 0.0 {
            break;
        }
        chosen.push(next);
    }
    chosen
        .into_iter()
        .filter(|&anchor| anchor_admissible(metric, anchor))
        .map(|anchor| {
            let row = metric.row(anchor);
            let mut perm: Vec<usize> = (0..d).collect();
            perm.sort_by(|&a, &b| row[a].total_cmp(&row[b]).then(a.cmp(&b)));
            let gaps = perm
                .windows(2)
                .map(|w| row[w[1]] - row[w[0]])
                .collect();
            AnchorAxis { anchor, perm, gaps }
        })
        .collect()
}

/// Append the permuted prefix sums of `values` (all but the final 1.0)
/// to `table`.
fn push_sorted_cdf(table: &mut Vec<F>, values: &[F], perm: &[usize]) {
    let mut acc = 0.0;
    for &i in &perm[..perm.len().saturating_sub(1)] {
        acc += values[i];
        table.push(acc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::RandomMetric;
    use crate::ot::onedim::projection_lower_bound;
    use crate::simplex::seeded_rng;

    fn corpus(d: usize, n: usize, seed: u64) -> (CostMatrix, Vec<Histogram>) {
        let mut rng = seeded_rng(seed);
        let m = RandomMetric::new(d).sample(&mut rng);
        let entries =
            (0..n).map(|_| Histogram::sample_uniform(d, &mut rng)).collect();
        (m, entries)
    }

    #[test]
    fn builds_and_validates() {
        let (m, entries) = corpus(12, 20, 0);
        let index = CorpusIndex::from_histograms(&m, entries, 4).unwrap();
        assert_eq!(index.len(), 20);
        assert_eq!(index.dim(), 12);
        assert_eq!(index.anchors().len(), 4);
        assert!(index.has_centroid_space(), "Euclidean metric must embed");
        // Anchors are distinct.
        let mut a = index.anchors();
        a.sort_unstable();
        a.dedup();
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn rejects_bad_input() {
        let (m, mut entries) = corpus(12, 4, 1);
        assert!(matches!(
            CorpusIndex::from_histograms(&m, Vec::new(), 4),
            Err(RetrievalError::EmptyCorpus)
        ));
        entries[2] = Histogram::uniform(9);
        assert!(matches!(
            CorpusIndex::from_histograms(&m, entries, 4),
            Err(RetrievalError::DimensionMismatch { entry: 2, got: 9, want: 12 })
        ));
        let rows = vec![vec![1.0, 2.0], vec![-1.0, 1.0]];
        let m2 = CostMatrix::from_rows(2, vec![0.0, 1.0, 1.0, 0.0]);
        assert!(matches!(
            CorpusIndex::from_weights(&m2, &rows, 2),
            Err(RetrievalError::BadEntry { entry: 1, .. })
        ));
    }

    #[test]
    fn from_weights_normalizes() {
        let m = CostMatrix::from_rows(2, vec![0.0, 1.0, 1.0, 0.0]);
        let index =
            CorpusIndex::from_weights(&m, &[vec![2.0, 2.0], vec![1.0, 3.0]], 2)
                .unwrap();
        assert_eq!(index.entry(0).values(), &[0.5, 0.5]);
        assert_eq!(index.entry(1).values(), &[0.25, 0.75]);
    }

    #[test]
    fn cached_projection_bound_matches_uncached_helper() {
        let (m, entries) = corpus(16, 12, 2);
        let index = CorpusIndex::from_histograms(&m, entries.clone(), 3).unwrap();
        let mut rng = seeded_rng(20);
        let q = Histogram::sample_uniform(16, &mut rng);
        let prep = index.prepare(&q);
        for e in 0..entries.len() {
            let cached = index.projection_bound(&prep, e);
            let direct = index
                .anchors()
                .iter()
                .map(|&a| projection_lower_bound(&m, a, &q, &entries[e]))
                .fold(0.0, F::max);
            assert!(
                (cached - direct).abs() < 1e-12,
                "entry {e}: cached {cached} vs direct {direct}"
            );
        }
    }

    #[test]
    fn warm_cache_round_trips_per_entry() {
        let (m, entries) = corpus(8, 3, 3);
        let mut index = CorpusIndex::from_histograms(&m, entries, 2).unwrap();
        assert!(index.warm_init(9.0, 1).is_none());
        let out = SinkhornOutput {
            value: 1.0,
            u: vec![1.0; 8],
            v: vec![2.0; 8],
            stats: crate::sinkhorn::SinkhornStats {
                converged: true,
                ..Default::default()
            },
        };
        index.warm_deposit(9.0, 1, &out);
        let init = index.warm_init(9.0, 1).expect("cached");
        let (u, v) = init.scalings().expect("warm seed carries scalings");
        assert_eq!(u, &[1.0; 8]);
        assert_eq!(v, &[2.0; 8]);
        // Different λ or entry misses; unconverged solves are not kept.
        assert!(index.warm_init(3.0, 1).is_none());
        assert!(index.warm_init(9.0, 0).is_none());
        let bad = SinkhornOutput {
            stats: crate::sinkhorn::SinkhornStats::default(),
            ..out
        };
        index.warm_deposit(9.0, 2, &bad);
        assert!(index.warm_init(9.0, 2).is_none());
        assert!(index.warm_counters().hits >= 1);
    }

    #[test]
    fn non_metric_costs_disable_the_projection_tier() {
        // Squared-Euclidean costs violate the triangle inequality, so
        // every projection anchor must be rejected — an admissible index
        // still builds (mass + centroid tiers), it just never offers an
        // inflated projection "lower" bound.
        use crate::metric::GridMetric;
        let m = GridMetric::new(3, 3).squared_cost_matrix();
        let mut rng = seeded_rng(40);
        let entries: Vec<Histogram> =
            (0..8).map(|_| Histogram::sample_uniform(9, &mut rng)).collect();
        let index = CorpusIndex::from_histograms(&m, entries.clone(), 4).unwrap();
        // Farthest-point selection picks the four grid corners here, and
        // every corner projection violates the reverse triangle on
        // squared costs (desk-computed; the center anchor would pass the
        // pairwise check but is never selected), so the tier empties.
        assert!(index.anchors().is_empty(), "no admissible anchor on squared costs");
        assert!(index.has_centroid_space(), "squared EDM still embeds");
        let q = Histogram::sample_uniform(9, &mut rng);
        let prep = index.prepare(&q);
        // The surviving tiers stay admissible against the exact optimum.
        use crate::ot::EmdSolver;
        let solver = EmdSolver::new(&m);
        for (e, c) in entries.iter().enumerate() {
            assert_eq!(index.projection_bound(&prep, e), 0.0);
            let exact = solver.solve(&q, c).unwrap().cost;
            let centroid = index.centroid_bound(&prep, e).unwrap();
            assert!(centroid <= exact + 1e-9, "entry {e}: {centroid} > {exact}");
            assert!(index.mass_bound(&q, e) <= exact + 1e-9);
        }
        // A genuine metric keeps its full anchor set.
        let plain = GridMetric::new(3, 3).cost_matrix();
        let index = CorpusIndex::from_histograms(&plain, entries, 4).unwrap();
        assert_eq!(index.anchors().len(), 4);
    }

    #[test]
    fn pushed_entries_match_a_from_scratch_build() {
        // Incremental push must produce bit-identical per-entry
        // statistics to indexing the grown corpus from scratch: the
        // axes and embedding are metric-only, so the appended CDF rows
        // and prepared coordinates go through the exact same code path.
        let (m, entries) = corpus(14, 10, 5);
        let mut grown =
            CorpusIndex::from_histograms(&m, entries[..6].to_vec(), 3).unwrap();
        for h in &entries[6..] {
            let slot = grown.push(h.clone()).unwrap();
            assert_eq!(slot, grown.len() - 1);
        }
        let scratch = CorpusIndex::from_histograms(&m, entries.clone(), 3).unwrap();
        assert_eq!(grown.len(), scratch.len());
        assert_eq!(grown.anchors(), scratch.anchors());
        assert_eq!(grown.anchors_requested(), 3);
        let mut rng = seeded_rng(55);
        let q = Histogram::sample_uniform(14, &mut rng);
        let gp = grown.prepare(&q);
        let sp = scratch.prepare(&q);
        for e in 0..entries.len() {
            assert_eq!(grown.entry(e).values(), scratch.entry(e).values());
            assert_eq!(grown.projection_bound(&gp, e), scratch.projection_bound(&sp, e));
            assert_eq!(grown.mass_bound(&q, e), scratch.mass_bound(&q, e));
            assert_eq!(grown.centroid_bound(&gp, e), scratch.centroid_bound(&sp, e));
        }
        // Dimension mismatches are rejected without mutating the index.
        let err = grown.push(Histogram::uniform(9)).unwrap_err();
        assert!(matches!(
            err,
            RetrievalError::DimensionMismatch { entry: 10, got: 9, want: 14 }
        ));
        assert_eq!(grown.len(), 10);
    }

    #[test]
    fn single_bin_corpus_degenerates_gracefully() {
        let m = CostMatrix::from_rows(1, vec![0.0]);
        let index =
            CorpusIndex::from_histograms(&m, vec![Histogram::uniform(1)], 4).unwrap();
        let q = Histogram::uniform(1);
        let prep = index.prepare(&q);
        assert_eq!(index.mass_bound(&q, 0), 0.0);
        assert_eq!(index.projection_bound(&prep, 0), 0.0);
    }
}
