//! # sinkhorn-rs — Lightspeed Optimal Transportation Distances
//!
//! A production-grade reproduction of *Cuturi, "Sinkhorn Distances:
//! Lightspeed Computation of Optimal Transportation Distances"* (2013),
//! built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)** — the Sinkhorn-Knopp fixed-point iteration
//!   is written as a Pallas kernel inside a batched JAX program and
//!   AOT-lowered to HLO text artifacts (`python/compile/`, `artifacts/`).
//! * **Layer 3 (this crate)** — a Rust coordinator that loads the
//!   artifacts through PJRT ([`runtime`]), routes and batches distance
//!   queries ([`coordinator`]), executes panels across a sharded
//!   thread-pool of pluggable solver strategies ([`backend`]), answers
//!   corpus-scale top-k queries through a pruned bound-then-refine
//!   cascade ([`retrieval`]), and ships
//!   every substrate the paper's evaluation needs: an exact EMD solver
//!   ([`ot`]), a pure-Rust Sinkhorn engine ([`sinkhorn`]), classical
//!   histogram distances ([`distances`]), a kernel SVM ([`svm`]),
//!   ground-metric builders ([`metric`]) and workload generators
//!   ([`data`], [`simplex`]).
//!
//! See `README.md` for the build, test and CI instructions and the
//! system inventory.
//!
//! ## Quickstart
//!
//! ```no_run
//! use sinkhorn_rs::prelude::*;
//!
//! // A ground metric over a 4x4 pixel grid and two random histograms.
//! let m = GridMetric::new(4, 4).cost_matrix();
//! let mut rng = seeded_rng(0);
//! let r = Histogram::sample_uniform(16, &mut rng);
//! let c = Histogram::sample_uniform(16, &mut rng);
//!
//! // Exact optimal transportation distance (network simplex)...
//! let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
//! // ...and its entropically-smoothed Sinkhorn counterpart.
//! let sk = SinkhornEngine::new(&m, 9.0).distance(&r, &c);
//! assert!(sk.value >= exact - 1e-9);
//! ```

// Index-arithmetic-heavy numeric kernels: explicit `for i in 0..d` loops
// over row-major buffers are the house style (they mirror the paper's
// matrix notation), so the iterator-translation lint stays off.
#![allow(clippy::needless_range_loop)]
// Channel-of-channels plumbing (per-query response channels) is the
// coordinator's core pattern; the nested types are intentional.
#![allow(clippy::type_complexity)]

pub mod backend;
pub mod coordinator;
pub mod data;
pub mod distances;
pub mod exp;
pub mod linalg;
pub mod metric;
pub mod ot;
pub mod retrieval;
pub mod rng;
pub mod runtime;
pub mod simplex;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod sinkhorn;
pub mod svm;

/// Crate-wide scalar type for host-side (exact) computation. The XLA/PJRT
/// artifacts are f32; conversion happens at the [`runtime`] boundary.
pub type F = f64;

/// Convenience re-exports covering the public API surface.
pub mod prelude {
    pub use crate::backend::{BackendKind, ShardedExecutor, SolverBackend};
    pub use crate::coordinator::{
        BatcherConfig, CoordinatorConfig, CoordinatorConfigBuilder, DistanceService,
        Query, QueryResult, WarmStartConfig,
    };
    pub use crate::data::{ClusteredCorpus, DigitClass, SyntheticDigits};
    pub use crate::distances::{ClassicalDistance, KernelBuilder};
    pub use crate::linalg::{KernelOp, KernelPolicy, KernelStats};
    pub use crate::metric::{CostMatrix, GridMetric, RandomMetric};
    pub use crate::ot::{EmdSolver, TransportPlan};
    pub use crate::retrieval::{
        BoundCascade, CorpusIndex, RetrievalConfig, RetrievalRuntime,
        RetrievalService, ShardedCorpus, ShardingConfig,
    };
    pub use crate::rng::Rng;
    pub use crate::simplex::{seeded_rng, Histogram};
    pub use crate::sinkhorn::{
        independence_distance, ErrorInterval, IndependenceKernel, LambdaSchedule,
        ScalingInit, SinkhornConfig, SinkhornEngine, SolveBudget, SolveOutcome,
        WarmStartStore,
    };
    pub use crate::svm::{MulticlassSvm, SvmConfig};
    pub use crate::telemetry::{SloPolicy, TelemetryConfig, TelemetryReport};
    pub use crate::trace::{TraceConfig, TraceSink};
    pub use crate::F;
}
