//! Kernel construction for SVM classification (paper §5.1.1).
//!
//! For each candidate distance d the paper builds the (generally
//! indefinite) kernel k(x,y) = e^{−d(x,y)/t}, selects the bandwidth t by
//! cross-validation within {1, q10(d), q20(d), q50(d)} (quantiles of
//! observed training distances), and "regularize[s] non-positive definite
//! kernel matrices ... by adding a sufficiently large diagonal term".

use crate::linalg::{cholesky, quantile, Matrix};
use crate::F;

/// The paper's bandwidth grid {1, q10, q20, q50} computed from a sample of
/// training-fold distances. Degenerate (zero / duplicate) quantiles are
/// clamped to a tiny positive floor so e^{-d/t} stays well-defined.
pub fn quantile_bandwidths(observed_distances: &[F]) -> Vec<F> {
    let mut grid = vec![1.0];
    for s in [0.10, 0.20, 0.50] {
        grid.push(quantile(observed_distances, s).max(1e-12));
    }
    grid.dedup_by(|a, b| (*a - *b).abs() < 1e-15);
    grid
}

/// A symmetric kernel Gram matrix, tracked with the diagonal shift that
/// was applied to make it numerically PSD.
#[derive(Debug, Clone)]
pub struct KernelMatrix {
    gram: Matrix,
    diagonal_shift: F,
}

impl KernelMatrix {
    #[inline]
    pub fn gram(&self) -> &Matrix {
        &self.gram
    }

    /// The τ that was added to the diagonal (0 when already PSD).
    #[inline]
    pub fn diagonal_shift(&self) -> F {
        self.diagonal_shift
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> F {
        self.gram.get(i, j)
    }

    pub fn size(&self) -> usize {
        self.gram.rows()
    }
}

/// Builds e^{−d/t} kernels from precomputed distance matrices.
#[derive(Debug, Clone, Copy)]
pub struct KernelBuilder {
    /// Bandwidth t > 0.
    pub bandwidth: F,
}

impl KernelBuilder {
    pub fn new(bandwidth: F) -> Self {
        assert!(bandwidth > 0.0, "bandwidth must be positive");
        Self { bandwidth }
    }

    /// Train-side square Gram matrix: symmetrize, exponentiate and shift
    /// the diagonal until a Cholesky factorization succeeds (the paper's
    /// "sufficiently large diagonal term", found by doubling).
    pub fn square_gram(&self, dist: &Matrix) -> KernelMatrix {
        assert_eq!(dist.rows(), dist.cols(), "train Gram needs square input");
        let n = dist.rows();
        let mut gram = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                // Average the two triangles: guards against tiny asymmetry
                // from approximate distance computations.
                let d = 0.5 * (dist.get(i, j) + dist.get(j, i));
                gram.set(i, j, (-d / self.bandwidth).exp());
            }
        }
        let diagonal_shift = make_psd(&mut gram);
        KernelMatrix { gram, diagonal_shift }
    }

    /// Rectangular test-vs-train kernel block (no PSD repair needed).
    pub fn cross_gram(&self, dist: &Matrix) -> Matrix {
        dist.map(|d| (-d / self.bandwidth).exp())
    }
}

/// Add τ·I with τ doubling from a small seed until Cholesky succeeds.
/// Returns the final τ (0 if the matrix was already PD).
fn make_psd(gram: &mut Matrix) -> F {
    if cholesky(gram).is_some() {
        return 0.0;
    }
    let n = gram.rows();
    // Seed relative to the average diagonal magnitude.
    let avg_diag: F =
        (0..n).map(|i| gram.get(i, i).abs()).sum::<F>() / n.max(1) as F;
    let mut tau = (1e-10 * avg_diag).max(1e-12);
    let mut applied = 0.0;
    for _ in 0..64 {
        let add = tau - applied;
        for i in 0..n {
            let v = gram.get(i, i) + add;
            gram.set(i, i, v);
        }
        applied = tau;
        if cholesky(gram).is_some() {
            return applied;
        }
        tau *= 2.0;
    }
    panic!("make_psd failed to repair the kernel after 64 doublings");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;

    #[test]
    fn bandwidth_grid_contains_one_and_quantiles() {
        let d: Vec<F> = (1..=100).map(|i| i as F).collect();
        let grid = quantile_bandwidths(&d);
        assert_eq!(grid[0], 1.0);
        assert_eq!(grid.len(), 4);
        assert!((grid[3] - 50.5).abs() < 1e-9); // median of 1..=100
    }

    #[test]
    fn bandwidth_grid_clamps_zero_quantiles() {
        let grid = quantile_bandwidths(&[0.0, 0.0, 0.0, 5.0]);
        assert!(grid.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn gaussian_kernel_on_sq_euclidean_is_psd_without_shift() {
        // e^{-||x-y||^2 / t} is PD, so no diagonal repair should trigger.
        let pts: Vec<F> = vec![0.0, 1.0, 2.5, 4.0];
        let n = pts.len();
        let mut dist = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                dist.set(i, j, (pts[i] - pts[j]) * (pts[i] - pts[j]));
            }
        }
        let k = KernelBuilder::new(1.0).square_gram(&dist);
        assert_eq!(k.diagonal_shift(), 0.0);
    }

    #[test]
    fn indefinite_kernel_gets_repaired() {
        // A triangle-violating "distance" chain 0—1—2: near-zero distances
        // along the chain, huge across it. The e^{-d} Gram is
        // [[1, ~1, 0], [~1, 1, ~1], [0, ~1, 1]], whose smallest eigenvalue
        // is 1 - sqrt(2)*0.99 < 0.
        let mut dist = Matrix::zeros(3, 3);
        dist.set(0, 1, 0.01);
        dist.set(1, 0, 0.01);
        dist.set(1, 2, 0.01);
        dist.set(2, 1, 0.01);
        dist.set(0, 2, 50.0);
        dist.set(2, 0, 50.0);
        let k = KernelBuilder::new(1.0).square_gram(&dist);
        assert!(k.diagonal_shift() > 0.0, "expected a PSD repair");
        assert!(cholesky(k.gram()).is_some());
    }

    #[test]
    fn cross_gram_matches_formula() {
        let mut dist = Matrix::zeros(2, 3);
        dist.set(0, 1, 2.0);
        dist.set(1, 2, 4.0);
        let k = KernelBuilder::new(2.0).cross_gram(&dist);
        assert!((k.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((k.get(0, 1) - (-1.0 as F).exp()).abs() < 1e-12);
        assert!((k.get(1, 2) - (-2.0 as F).exp()).abs() < 1e-12);
    }

    #[test]
    fn repaired_gram_stays_close() {
        // The shift only touches the diagonal.
        let n = 3;
        let mut dist = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    dist.set(i, j, if (i + j) % 2 == 0 { 0.001 } else { 9.0 });
                }
            }
        }
        let kb = KernelBuilder::new(1.0);
        let k = kb.square_gram(&dist);
        let raw = kb.cross_gram(&dist);
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    assert!((k.get(i, j) - raw.get(i, j)).abs() < 1e-12);
                }
            }
        }
        let _ = gemm(k.gram(), k.gram()); // smoke: usable downstream
    }
}
