//! Classical (non-transportation) histogram distances and kernel builders.
//!
//! These are the Figure 2 baselines of §5.1.2: Hellinger, χ², Total
//! Variation, squared Euclidean (the Gaussian kernel's exponent) and
//! Mahalanobis — the distances the paper compares Sinkhorn against —
//! plus the experimental plumbing around them: the `e^{-d/t}` kernel with
//! its quantile-based bandwidth grid and the "add a sufficiently large
//! diagonal term" PSD regularization.

mod kernels;

pub use kernels::{quantile_bandwidths, KernelBuilder, KernelMatrix};

use crate::linalg::Matrix;
use crate::simplex::Histogram;
use crate::F;

/// The classical distances of the paper's §5.1.2 evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClassicalDistance {
    /// H(r,c) = sqrt( Σ (√r_i − √c_i)² ) (up to the customary 1/√2).
    Hellinger,
    /// χ²(r,c) = Σ (r_i − c_i)² / (r_i + c_i), with 0/0 := 0.
    ChiSquare,
    /// TV(r,c) = ½ Σ |r_i − c_i|.
    TotalVariation,
    /// ‖r − c‖₂² — the exponent of the Gaussian kernel.
    SquaredEuclidean,
}

impl ClassicalDistance {
    /// All Figure 2 classical baselines, in presentation order.
    pub const ALL: [ClassicalDistance; 4] = [
        ClassicalDistance::Hellinger,
        ClassicalDistance::ChiSquare,
        ClassicalDistance::TotalVariation,
        ClassicalDistance::SquaredEuclidean,
    ];

    /// Display name used by harness tables.
    pub fn name(&self) -> &'static str {
        match self {
            ClassicalDistance::Hellinger => "hellinger",
            ClassicalDistance::ChiSquare => "chi2",
            ClassicalDistance::TotalVariation => "total_variation",
            ClassicalDistance::SquaredEuclidean => "sq_euclidean",
        }
    }

    /// Evaluate the distance between two histograms.
    pub fn eval(&self, r: &Histogram, c: &Histogram) -> F {
        assert_eq!(r.dim(), c.dim(), "histogram dimensions differ");
        let (a, b) = (r.values(), c.values());
        match self {
            ClassicalDistance::Hellinger => a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let d = x.sqrt() - y.sqrt();
                    d * d
                })
                .sum::<F>()
                .sqrt(),
            ClassicalDistance::ChiSquare => a
                .iter()
                .zip(b)
                .map(|(&x, &y)| {
                    let s = x + y;
                    if s > 0.0 {
                        (x - y) * (x - y) / s
                    } else {
                        0.0
                    }
                })
                .sum(),
            ClassicalDistance::TotalVariation => {
                0.5 * a.iter().zip(b).map(|(&x, &y)| (x - y).abs()).sum::<F>()
            }
            ClassicalDistance::SquaredEuclidean => {
                a.iter().zip(b).map(|(&x, &y)| (x - y) * (x - y)).sum()
            }
        }
    }
}

/// Mahalanobis-style quadratic form d(r,c) = (r−c)ᵀ W (r−c) for a PSD
/// weight matrix W. §5.1.2 tries W = exp(−t·M∘M) and its inverse; the
/// harness builds those via [`Matrix::map`].
#[derive(Debug, Clone)]
pub struct MahalanobisDistance {
    weight: Matrix,
}

impl MahalanobisDistance {
    pub fn new(weight: Matrix) -> Self {
        assert_eq!(weight.rows(), weight.cols(), "weight must be square");
        Self { weight }
    }

    /// The identity weight recovers squared Euclidean distance.
    pub fn identity(d: usize) -> Self {
        let mut w = Matrix::zeros(d, d);
        for i in 0..d {
            w.set(i, i, 1.0);
        }
        Self { weight: w }
    }

    pub fn eval(&self, r: &Histogram, c: &Histogram) -> F {
        assert_eq!(r.dim(), self.weight.rows(), "dimension mismatch");
        assert_eq!(r.dim(), c.dim(), "histogram dimensions differ");
        let diff: Vec<F> =
            r.values().iter().zip(c.values()).map(|(&x, &y)| x - y).collect();
        let wd = self.weight.matvec(&diff);
        crate::linalg::dot(&diff, &wd)
    }
}

/// Pairwise distance matrix between two histogram collections (rows:
/// `left`, cols: `right`), the raw material for every Gram matrix in the
/// Figure 2 pipeline.
pub fn pairwise(
    dist: impl Fn(&Histogram, &Histogram) -> F + Sync,
    left: &[Histogram],
    right: &[Histogram],
) -> Matrix {
    let mut out = Matrix::zeros(left.len(), right.len());
    for (i, r) in left.iter().enumerate() {
        for (j, c) in right.iter().enumerate() {
            out.set(i, j, dist(r, c));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::seeded_rng;

    fn h(v: &[F]) -> Histogram {
        Histogram::from_weights(v).unwrap()
    }

    #[test]
    fn known_values() {
        let r = h(&[1.0, 0.0]);
        let c = h(&[0.0, 1.0]);
        assert!((ClassicalDistance::Hellinger.eval(&r, &c) - (2.0 as F).sqrt()).abs() < 1e-12);
        assert!((ClassicalDistance::ChiSquare.eval(&r, &c) - 2.0).abs() < 1e-12);
        assert!((ClassicalDistance::TotalVariation.eval(&r, &c) - 1.0).abs() < 1e-12);
        assert!((ClassicalDistance::SquaredEuclidean.eval(&r, &c) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn chi2_handles_joint_zeros() {
        let r = h(&[1.0, 0.0, 0.0]);
        let c = h(&[1.0, 0.0, 0.0]);
        assert_eq!(ClassicalDistance::ChiSquare.eval(&r, &c), 0.0);
    }

    #[test]
    fn mahalanobis_identity_is_sq_euclidean() {
        let mut rng = seeded_rng(4);
        let r = Histogram::sample_uniform(12, &mut rng);
        let c = Histogram::sample_uniform(12, &mut rng);
        let maha = MahalanobisDistance::identity(12);
        let want = ClassicalDistance::SquaredEuclidean.eval(&r, &c);
        assert!((maha.eval(&r, &c) - want).abs() < 1e-12);
    }

    #[test]
    fn pairwise_shape_and_diagonal() {
        let mut rng = seeded_rng(9);
        let set: Vec<Histogram> =
            (0..5).map(|_| Histogram::sample_uniform(8, &mut rng)).collect();
        let m = pairwise(
            |a, b| ClassicalDistance::Hellinger.eval(a, b),
            &set,
            &set,
        );
        assert_eq!((m.rows(), m.cols()), (5, 5));
        for i in 0..5 {
            assert_eq!(m.get(i, i), 0.0);
            for j in 0..5 {
                assert!((m.get(i, j) - m.get(j, i)).abs() < 1e-12);
            }
        }
    }

    /// All four classical distances are symmetric, non-negative and
    /// satisfy the coincidence axiom on random histograms.
    #[test]
    fn prop_distance_axioms() {
        for seed in 0..150u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 40);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            for dist in ClassicalDistance::ALL {
                let rc = dist.eval(&r, &c);
                let cr = dist.eval(&c, &r);
                assert!(rc >= 0.0);
                assert!((rc - cr).abs() < 1e-12);
                assert!(dist.eval(&r, &r).abs() < 1e-12);
            }
        }
    }

    /// TV is bounded by 1; Hellinger by sqrt(2).
    #[test]
    fn prop_known_bounds() {
        for seed in 0..150u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 40);
            let r = Histogram::sample_dirichlet(d, 0.3, &mut rng);
            let c = Histogram::sample_dirichlet(d, 0.3, &mut rng);
            assert!(ClassicalDistance::TotalVariation.eval(&r, &c) <= 1.0 + 1e-12);
            assert!(ClassicalDistance::Hellinger.eval(&r, &c) <= (2.0 as F).sqrt() + 1e-12);
        }
    }
}
