//! Binary soft-margin SVM trained by Sequential Minimal Optimization.
//!
//! Working-set selection follows libsvm's first-order heuristic: the
//! maximal-violating pair (i, j) over the KKT conditions, with an error
//! cache updated incrementally after each two-variable analytic solve.
//! Operates on a precomputed kernel (Gram) matrix.

use super::SvmConfig;
use crate::linalg::{dot, Matrix};
use crate::F;

/// Re-export alias so harness code can spell the config at the SMO level.
pub type SmoConfig = SvmConfig;

/// A trained binary machine: support coefficients and bias.
#[derive(Debug, Clone)]
pub struct BinarySvm {
    /// alpha_i * y_i for every training point (zero off-support).
    coef: Vec<F>,
    bias: F,
    /// Number of SMO pair updates performed.
    pub iterations: usize,
}

impl BinarySvm {
    /// Train on a precomputed kernel. `y` must be ±1.
    pub fn train(kernel: &Matrix, y: &[F], config: SvmConfig) -> Self {
        let n = y.len();
        assert_eq!(kernel.rows(), n, "kernel/label size mismatch");
        assert_eq!(kernel.cols(), n, "kernel must be square");
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let c = config.c;
        let tol = config.tolerance;

        let mut alpha = vec![0.0; n];
        // Gradient of the dual objective: grad_i = sum_j alpha_j y_i y_j K_ij - 1.
        let mut grad = vec![-1.0; n];

        let mut iterations = 0;
        while iterations < config.max_iterations {
            // --- Maximal violating pair (Keerthi et al. / libsvm WSS1). ---
            // i = argmax_{t in I_up} -y_t grad_t ; j = argmin_{t in I_low}.
            let mut gmax = F::NEG_INFINITY;
            let mut gmin = F::INFINITY;
            let mut i_sel = usize::MAX;
            let mut j_sel = usize::MAX;
            for t in 0..n {
                let yt = y[t];
                let at = alpha[t];
                // I_up: can increase alpha_t*y_t direction.
                let in_up = (yt > 0.0 && at < c) || (yt < 0.0 && at > 0.0);
                // I_low: can decrease.
                let in_low = (yt > 0.0 && at > 0.0) || (yt < 0.0 && at < c);
                let v = -yt * grad[t];
                if in_up && v > gmax {
                    gmax = v;
                    i_sel = t;
                }
                if in_low && v < gmin {
                    gmin = v;
                    j_sel = t;
                }
            }
            if gmax - gmin < tol || i_sel == usize::MAX || j_sel == usize::MAX {
                break; // KKT-optimal within tolerance
            }
            let (i, j) = (i_sel, j_sel);
            iterations += 1;

            // --- Analytic two-variable solve (libsvm update form). ---
            let kii = kernel.get(i, i);
            let kjj = kernel.get(j, j);
            let kij = kernel.get(i, j);
            let eta = (kii + kjj - 2.0 * kij).max(1e-12);
            // delta along the feasible direction.
            let delta = (gmax - gmin) / eta;
            // Work in the alpha'_t = y_t alpha_t parameterization.
            let (yi, yj) = (y[i], y[j]);
            let mut dai = yi * delta; // change of alpha_i
            #[allow(unused_assignments)]
            let mut daj; // change of alpha_j (set below from dai)

            // Clip to the box [0, C] jointly.
            let ai_new = (alpha[i] + dai).clamp(0.0, c);
            dai = ai_new - alpha[i];
            daj = -yj * yi * dai;
            let aj_new = (alpha[j] + daj).clamp(0.0, c);
            let daj_clipped = aj_new - alpha[j];
            if (daj_clipped - daj).abs() > 0.0 {
                // j hit the box first; recompute i's step.
                daj = daj_clipped;
                dai = -yi * yj * daj;
            }
            if dai.abs() < 1e-16 && daj.abs() < 1e-16 {
                break; // numerically stuck: treat as converged
            }
            alpha[i] += dai;
            alpha[j] += daj;

            // --- Incremental gradient update. ---
            let ci = yi * dai;
            let cj = yj * daj;
            for t in 0..n {
                grad[t] += y[t] * (ci * kernel.get(i, t) + cj * kernel.get(j, t));
            }
        }

        // Bias from the free support vectors (average of y_t - w·x_t), or
        // the KKT midpoint when none are strictly inside the box.
        let coef: Vec<F> = alpha.iter().zip(y).map(|(&a, &yt)| a * yt).collect();
        let mut bias_sum = 0.0;
        let mut bias_cnt = 0usize;
        for t in 0..n {
            if alpha[t] > 1e-9 && alpha[t] < c - 1e-9 {
                let wx = dot(&coef, kernel.row(t));
                bias_sum += y[t] - wx;
                bias_cnt += 1;
            }
        }
        let bias = if bias_cnt > 0 {
            bias_sum / bias_cnt as F
        } else {
            // Midpoint of the violating-pair bounds.
            let mut up = F::INFINITY;
            let mut lo = F::NEG_INFINITY;
            for t in 0..n {
                let wx = dot(&coef, kernel.row(t));
                let margin = y[t] - wx;
                if (y[t] > 0.0 && alpha[t] < c - 1e-9) || (y[t] < 0.0 && alpha[t] > 1e-9) {
                    up = up.min(margin);
                }
                if (y[t] > 0.0 && alpha[t] > 1e-9) || (y[t] < 0.0 && alpha[t] < c - 1e-9) {
                    lo = lo.max(margin);
                }
            }
            if up.is_finite() && lo.is_finite() {
                0.5 * (up + lo)
            } else {
                0.0
            }
        };

        Self { coef, bias, iterations }
    }

    /// Decision value f(x) = Σ_t α_t y_t K(x_t, x) + b given the kernel
    /// row of x against the training set this machine saw.
    pub fn decision(&self, kernel_row: &[F]) -> F {
        debug_assert_eq!(kernel_row.len(), self.coef.len());
        dot(&self.coef, kernel_row) + self.bias
    }

    /// Number of support vectors (nonzero α).
    pub fn support_count(&self) -> usize {
        self.coef.iter().filter(|&&a| a.abs() > 1e-12).count()
    }

    /// The signed coefficients α_t y_t.
    pub fn coefficients(&self) -> &[F] {
        &self.coef
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear kernel on 1-D points as a transparent test bed.
    fn linear_gram(pts: &[F]) -> Matrix {
        let n = pts.len();
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k.set(i, j, pts[i] * pts[j]);
            }
        }
        k
    }

    #[test]
    fn separable_line() {
        let pts: Vec<F> = vec![-2.0, -1.5, -1.0, 1.0, 1.5, 2.0];
        let y: Vec<F> = vec![-1.0, -1.0, -1.0, 1.0, 1.0, 1.0];
        let k = linear_gram(&pts);
        let svm = BinarySvm::train(&k, &y, SvmConfig { c: 10.0, ..Default::default() });
        for (i, &yi) in y.iter().enumerate() {
            let f = svm.decision(k.row(i));
            assert!(f * yi > 0.0, "point {i} misclassified (f={f})");
        }
        // Margin points should be the support vectors.
        assert!(svm.support_count() <= 4);
    }

    #[test]
    fn decision_is_affine_in_kernel_row() {
        let pts: Vec<F> = vec![-1.0, 0.5, 2.0];
        let y: Vec<F> = vec![-1.0, 1.0, 1.0];
        let k = linear_gram(&pts);
        let svm = BinarySvm::train(&k, &y, SvmConfig::default());
        // f(x) for x=3 via kernel row = pts * 3.
        let row: Vec<F> = pts.iter().map(|&p| 3.0 * p).collect();
        let f3 = svm.decision(&row);
        assert!(f3 > 0.0);
    }

    #[test]
    fn box_constraint_is_respected() {
        // Noisy overlapping labels force alphas to the C bound.
        let pts: Vec<F> = vec![-1.0, -0.5, 0.5, 1.0, -0.4, 0.4];
        let y: Vec<F> = vec![-1.0, -1.0, 1.0, 1.0, 1.0, -1.0]; // last two flipped
        let k = linear_gram(&pts);
        let c = 0.5;
        let svm = BinarySvm::train(&k, &y, SvmConfig { c, ..Default::default() });
        for (t, &coef) in svm.coefficients().iter().enumerate() {
            assert!(
                coef.abs() <= c + 1e-9,
                "alpha[{t}] escaped the box: {coef}"
            );
        }
    }

    #[test]
    fn dual_constraint_sum_alpha_y_zero() {
        let pts: Vec<F> = vec![-2.0, -1.0, 0.2, 1.0, 2.0, 2.5];
        let y: Vec<F> = vec![-1.0, -1.0, 1.0, 1.0, 1.0, 1.0];
        let k = linear_gram(&pts);
        let svm = BinarySvm::train(&k, &y, SvmConfig { c: 5.0, ..Default::default() });
        let s: F = svm.coefficients().iter().sum();
        assert!(s.abs() < 1e-8, "sum alpha_t y_t = {s}");
    }

    #[test]
    fn terminates_on_degenerate_kernel() {
        // All-zero kernel: nothing to learn, must not loop forever.
        let k = Matrix::zeros(4, 4);
        let y: Vec<F> = vec![1.0, 1.0, -1.0, -1.0];
        let svm = BinarySvm::train(&k, &y, SvmConfig::default());
        assert!(svm.iterations < 100);
    }
}
