//! Kernel Support Vector Machines — the §5.1 classification harness.
//!
//! The paper evaluates every distance through "SVM's ... run with libsvm
//! (one-vs-one) for multiclass classification". This module reimplements
//! that stack: a binary soft-margin SVM trained by Sequential Minimal
//! Optimization (Platt, 1998 — the algorithm inside libsvm), a one-vs-one
//! multiclass wrapper with majority voting, and the cross-validation
//! utilities the experimental protocol needs (folds, repeated splits, the
//! C grid 10^{−2:2:4}).
//!
//! Training operates on *precomputed kernel matrices* (libsvm's
//! `-t 4` mode) because every kernel in the study is of the form
//! e^{−d(x,y)/t} for an arbitrary distance d.

mod smo;

pub use smo::{BinarySvm, SmoConfig};

use crate::distances::KernelMatrix;
use crate::linalg::Matrix;
use crate::F;

/// Configuration shared by all classifiers in a one-vs-one ensemble.
#[derive(Debug, Clone, Copy)]
pub struct SvmConfig {
    /// Soft-margin penalty C. The paper's grid is 10^{-2:2:4}, i.e.
    /// {0.01, 1, 100, 10000}.
    pub c: F,
    /// KKT tolerance for SMO convergence (libsvm default 1e-3).
    pub tolerance: F,
    /// Hard cap on SMO iterations (pair optimizations).
    pub max_iterations: usize,
}

impl Default for SvmConfig {
    fn default() -> Self {
        Self { c: 1.0, tolerance: 1e-3, max_iterations: 100_000 }
    }
}

impl SvmConfig {
    /// The paper's C grid: 10^{-2:2:4}.
    pub fn c_grid() -> Vec<F> {
        vec![1e-2, 1e0, 1e2, 1e4]
    }
}

/// One-vs-one multiclass SVM over a precomputed kernel.
///
/// For k classes, trains k(k−1)/2 binary machines on the class-pair
/// sub-kernels and predicts by majority vote (ties broken toward the
/// smaller class label, as libsvm does).
#[derive(Debug)]
pub struct MulticlassSvm {
    classes: Vec<usize>,
    /// (class_a, class_b, machine, train indices used by the machine).
    machines: Vec<(usize, usize, BinarySvm, Vec<usize>)>,
}

impl MulticlassSvm {
    /// Train from a square training Gram matrix and integer labels.
    pub fn train(kernel: &KernelMatrix, labels: &[usize], config: SvmConfig) -> Self {
        let n = kernel.size();
        assert_eq!(labels.len(), n, "one label per training row");
        let mut classes: Vec<usize> = labels.to_vec();
        classes.sort_unstable();
        classes.dedup();
        assert!(classes.len() >= 2, "need at least two classes");

        let mut machines = Vec::with_capacity(classes.len() * (classes.len() - 1) / 2);
        for ai in 0..classes.len() {
            for bi in (ai + 1)..classes.len() {
                let (ca, cb) = (classes[ai], classes[bi]);
                // Collect the sub-problem: class a -> +1, class b -> -1.
                let idx: Vec<usize> = (0..n)
                    .filter(|&i| labels[i] == ca || labels[i] == cb)
                    .collect();
                let y: Vec<F> = idx
                    .iter()
                    .map(|&i| if labels[i] == ca { 1.0 } else { -1.0 })
                    .collect();
                let mut sub = Matrix::zeros(idx.len(), idx.len());
                for (p, &i) in idx.iter().enumerate() {
                    for (q, &j) in idx.iter().enumerate() {
                        sub.set(p, q, kernel.get(i, j));
                    }
                }
                let machine = BinarySvm::train(&sub, &y, config);
                machines.push((ca, cb, machine, idx));
            }
        }
        Self { classes, machines }
    }

    /// Class labels seen at training time.
    pub fn classes(&self) -> &[usize] {
        &self.classes
    }

    /// Predict one test point given its kernel row against the *full*
    /// training set (same column order as the training Gram).
    pub fn predict(&self, kernel_row: &[F]) -> usize {
        let mut votes: Vec<usize> = vec![0; self.classes.len()];
        for (ca, cb, machine, idx) in &self.machines {
            let sub_row: Vec<F> = idx.iter().map(|&i| kernel_row[i]).collect();
            let winner = if machine.decision(&sub_row) >= 0.0 { *ca } else { *cb };
            let slot = self.classes.iter().position(|&c| c == winner).unwrap();
            votes[slot] += 1;
        }
        // Majority vote; ties toward the smaller class index (libsvm).
        let mut best = 0;
        for (k, &v) in votes.iter().enumerate() {
            if v > votes[best] {
                best = k;
            }
        }
        self.classes[best]
    }

    /// Batch predict: `rows` is (n_test, n_train) of kernel evaluations.
    pub fn predict_batch(&self, rows: &Matrix) -> Vec<usize> {
        (0..rows.rows()).map(|i| self.predict(rows.row(i))).collect()
    }
}

/// Stratified k-fold assignment: returns a fold id in [0, k) per sample,
/// balanced per class. With `train_folds = 1` and k = 4 this is the
/// paper's "4 fold (3 test, 1 train)" protocol.
pub fn stratified_folds(
    labels: &[usize],
    k: usize,
    rng: &mut crate::rng::Rng,
) -> Vec<usize> {
    assert!(k >= 2, "need at least two folds");
    let mut fold = vec![0usize; labels.len()];
    let mut classes: Vec<usize> = labels.to_vec();
    classes.sort_unstable();
    classes.dedup();
    for c in classes {
        let mut members: Vec<usize> =
            (0..labels.len()).filter(|&i| labels[i] == c).collect();
        rng.shuffle(&mut members);
        for (rank, &i) in members.iter().enumerate() {
            fold[i] = rank % k;
        }
    }
    fold
}

/// Classification error rate.
pub fn error_rate(predicted: &[usize], truth: &[usize]) -> F {
    assert_eq!(predicted.len(), truth.len());
    if predicted.is_empty() {
        return 0.0;
    }
    let wrong = predicted.iter().zip(truth).filter(|(p, t)| p != t).count();
    wrong as F / predicted.len() as F
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::KernelBuilder;
    use crate::simplex::seeded_rng;

    /// Gaussian-kernel Gram from 1-D points (an easy linearly-structured
    /// problem for smoke tests).
    fn gram_from_points(pts: &[F], bw: F) -> KernelMatrix {
        let n = pts.len();
        let mut dist = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                dist.set(i, j, (pts[i] - pts[j]) * (pts[i] - pts[j]));
            }
        }
        KernelBuilder::new(bw).square_gram(&dist)
    }

    #[test]
    fn separable_two_class() {
        let pts: Vec<F> = vec![0.0, 0.1, 0.2, 0.3, 5.0, 5.1, 5.2, 5.3];
        let labels = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let gram = gram_from_points(&pts, 1.0);
        let svm = MulticlassSvm::train(&gram, &labels, SvmConfig::default());
        // Self-prediction should be perfect on a separable set.
        let preds: Vec<usize> =
            (0..8).map(|i| svm.predict(gram.gram().row(i))).collect();
        assert_eq!(preds, labels);
    }

    #[test]
    fn three_class_one_vs_one() {
        let pts: Vec<F> =
            vec![0.0, 0.2, 0.4, 10.0, 10.2, 10.4, 20.0, 20.2, 20.4];
        let labels = vec![0, 0, 0, 1, 1, 1, 2, 2, 2];
        let gram = gram_from_points(&pts, 4.0);
        let svm = MulticlassSvm::train(&gram, &labels, SvmConfig { c: 100.0, ..Default::default() });
        assert_eq!(svm.classes(), &[0, 1, 2]);
        assert_eq!(svm.machines.len(), 3);
        let preds: Vec<usize> =
            (0..9).map(|i| svm.predict(gram.gram().row(i))).collect();
        assert_eq!(preds, labels);
    }

    #[test]
    fn generalizes_to_new_points() {
        let train_pts: Vec<F> = vec![0.0, 0.3, 0.6, 8.0, 8.3, 8.6];
        let labels = vec![0, 0, 0, 1, 1, 1];
        let gram = gram_from_points(&train_pts, 2.0);
        let svm = MulticlassSvm::train(&gram, &labels, SvmConfig { c: 10.0, ..Default::default() });
        // Test kernel rows for unseen points 0.45 (class 0) and 7.5 (1).
        let kb = KernelBuilder::new(2.0);
        let mut dist = Matrix::zeros(2, 6);
        for (t, &x) in [0.45, 7.5].iter().enumerate() {
            for (j, &p) in train_pts.iter().enumerate() {
                dist.set(t, j, (x - p) * (x - p));
            }
        }
        let rows = kb.cross_gram(&dist);
        assert_eq!(svm.predict_batch(&rows), vec![0, 1]);
    }

    #[test]
    fn stratified_folds_are_balanced() {
        let labels: Vec<usize> = (0..40).map(|i| i % 4).collect();
        let mut rng = seeded_rng(0);
        let folds = stratified_folds(&labels, 4, &mut rng);
        for c in 0..4 {
            for f in 0..4 {
                let count = (0..40)
                    .filter(|&i| labels[i] == c && folds[i] == f)
                    .count();
                // 10 members per class over 4 folds: 2 or 3 each.
                assert!(count >= 2 && count <= 3, "class {c} fold {f}: {count}");
            }
        }
    }

    #[test]
    fn error_rate_basics() {
        assert_eq!(error_rate(&[1, 2, 3], &[1, 2, 3]), 0.0);
        assert_eq!(error_rate(&[1, 0, 3], &[1, 2, 3]), 1.0 / 3.0);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }

    #[test]
    fn noisy_problem_trains_without_panic() {
        let mut rng = seeded_rng(5);
        let n = 30;
        let pts: Vec<F> = (0..n)
            .map(|i| if i < n / 2 { rng.normal() } else { 3.0 + rng.normal() })
            .collect();
        let labels: Vec<usize> = (0..n).map(|i| (i >= n / 2) as usize).collect();
        let gram = gram_from_points(&pts, 1.0);
        for c in SvmConfig::c_grid() {
            let svm = MulticlassSvm::train(&gram, &labels, SvmConfig { c, ..Default::default() });
            let preds: Vec<usize> =
                (0..n).map(|i| svm.predict(gram.gram().row(i))).collect();
            // Overlapping Gaussians: expect far better than chance.
            assert!(error_rate(&preds, &labels) < 0.35);
        }
    }
}
