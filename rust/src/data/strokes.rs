//! Stroke templates for the ten digit classes.
//!
//! Each digit is a list of polylines with vertices in the unit square
//! ((0,0) = top-left, y growing downward, matching image row order).
//! Shapes are deliberately simple seven-segment-ish glyphs with curves
//! approximated by short polylines; the jitter in [`super::SyntheticDigits`]
//! supplies intra-class variability.

use crate::F;

/// Polyline vertex list type.
pub type Stroke = &'static [(F, F)];

/// `DIGIT_STROKES[c]` = the strokes of digit class `c`.
pub static DIGIT_STROKES: [&[Stroke]; 10] = [
    // 0: oval
    &[&[
        (0.50, 0.15),
        (0.68, 0.20),
        (0.75, 0.38),
        (0.75, 0.62),
        (0.68, 0.80),
        (0.50, 0.85),
        (0.32, 0.80),
        (0.25, 0.62),
        (0.25, 0.38),
        (0.32, 0.20),
        (0.50, 0.15),
    ]],
    // 1: vertical bar with a flag
    &[
        &[(0.38, 0.28), (0.52, 0.15), (0.52, 0.85)],
        &[(0.38, 0.85), (0.66, 0.85)],
    ],
    // 2: top arc, diagonal, base
    &[&[
        (0.28, 0.30),
        (0.35, 0.17),
        (0.55, 0.13),
        (0.70, 0.22),
        (0.72, 0.38),
        (0.55, 0.55),
        (0.38, 0.68),
        (0.27, 0.85),
        (0.74, 0.85),
    ]],
    // 3: two stacked arcs
    &[
        &[(0.30, 0.20), (0.50, 0.13), (0.68, 0.22), (0.68, 0.38), (0.50, 0.48)],
        &[(0.50, 0.48), (0.70, 0.57), (0.70, 0.75), (0.52, 0.86), (0.30, 0.79)],
    ],
    // 4: diagonal, horizontal, vertical
    &[
        &[(0.60, 0.15), (0.28, 0.60), (0.75, 0.60)],
        &[(0.60, 0.15), (0.60, 0.85)],
    ],
    // 5: top bar, left stem, lower bowl
    &[&[
        (0.70, 0.15),
        (0.32, 0.15),
        (0.30, 0.45),
        (0.55, 0.42),
        (0.72, 0.55),
        (0.72, 0.72),
        (0.55, 0.85),
        (0.30, 0.80),
    ]],
    // 6: descending curve with lower loop
    &[&[
        (0.66, 0.16),
        (0.45, 0.22),
        (0.32, 0.42),
        (0.28, 0.62),
        (0.38, 0.82),
        (0.58, 0.85),
        (0.70, 0.72),
        (0.66, 0.56),
        (0.48, 0.52),
        (0.32, 0.60),
    ]],
    // 7: top bar and diagonal
    &[&[(0.26, 0.16), (0.74, 0.16), (0.46, 0.85)]],
    // 8: two loops
    &[
        &[
            (0.50, 0.14),
            (0.66, 0.20),
            (0.66, 0.36),
            (0.50, 0.46),
            (0.34, 0.36),
            (0.34, 0.20),
            (0.50, 0.14),
        ],
        &[
            (0.50, 0.46),
            (0.70, 0.56),
            (0.70, 0.74),
            (0.50, 0.86),
            (0.30, 0.74),
            (0.30, 0.56),
            (0.50, 0.46),
        ],
    ],
    // 9: upper loop with descending tail
    &[&[
        (0.68, 0.40),
        (0.52, 0.48),
        (0.34, 0.42),
        (0.30, 0.26),
        (0.44, 0.14),
        (0.62, 0.16),
        (0.70, 0.30),
        (0.68, 0.55),
        (0.60, 0.75),
        (0.44, 0.86),
    ]],
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_classes_have_strokes_in_unit_square() {
        for (c, strokes) in DIGIT_STROKES.iter().enumerate() {
            assert!(!strokes.is_empty(), "class {c} has no strokes");
            for stroke in *strokes {
                assert!(stroke.len() >= 2, "class {c}: degenerate stroke");
                for &(x, y) in *stroke {
                    assert!((0.0..=1.0).contains(&x), "class {c}: x={x}");
                    assert!((0.0..=1.0).contains(&y), "class {c}: y={y}");
                }
            }
        }
    }

    #[test]
    fn glyphs_are_pairwise_distinct() {
        // Crude geometric distinctness: total vertex sets differ.
        for a in 0..10 {
            for b in (a + 1)..10 {
                assert_ne!(
                    DIGIT_STROKES[a], DIGIT_STROKES[b],
                    "classes {a} and {b} share identical strokes"
                );
            }
        }
    }
}
