//! Synthetic digit dataset — the §5.1 MNIST substitute.
//!
//! The paper's classification study runs on MNIST 20×20 intensity images
//! normalized into Σ₄₀₀ histograms. This environment has no network
//! access, so we build the closest synthetic equivalent that exercises the
//! identical code path (see README.md §Workloads): a procedural renderer that draws
//! each digit class 0–9 as a fixed set of strokes on the unit square,
//! rasterizes with a Gaussian pen onto a 20×20 grid, and perturbs each
//! sample with random affine jitter (translation / rotation / scale),
//! per-stroke endpoint noise and pixel noise. What the experiment needs is
//! preserved: ten visually-overlapping classes on the *same pixel grid*
//! whose confusions are spatially structured — exactly the regime where a
//! ground metric over pixels should help.

mod strokes;

pub use strokes::DIGIT_STROKES;

use crate::rng::Rng;
use crate::simplex::Histogram;
use crate::F;

/// One of the ten digit classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DigitClass(pub usize);

/// A labeled histogram sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub histogram: Histogram,
    pub label: usize,
}

/// Generator configuration.
#[derive(Debug, Clone, Copy)]
pub struct DigitConfig {
    /// Grid side (paper: 20 → d = 400).
    pub grid: usize,
    /// Gaussian pen radius as a fraction of the grid side.
    pub pen_sigma: F,
    /// Max translation jitter (fraction of side).
    pub translate: F,
    /// Max rotation jitter (radians).
    pub rotate: F,
    /// Scale jitter: scale ~ U[1-s, 1+s].
    pub scale: F,
    /// Endpoint wobble per stroke point (fraction of side).
    pub wobble: F,
    /// Additive uniform pixel noise amplitude (fraction of peak).
    pub pixel_noise: F,
}

impl Default for DigitConfig {
    fn default() -> Self {
        Self {
            grid: 20,
            pen_sigma: 0.045,
            translate: 0.08,
            rotate: 0.18,
            scale: 0.12,
            wobble: 0.02,
            pixel_noise: 0.02,
        }
    }
}

/// The synthetic-digits dataset generator.
#[derive(Debug, Clone)]
pub struct SyntheticDigits {
    config: DigitConfig,
}

impl SyntheticDigits {
    pub fn new(config: DigitConfig) -> Self {
        assert!(config.grid >= 4, "grid too small to draw digits");
        Self { config }
    }

    /// Default 20×20 generator (d = 400, like the paper's MNIST variant).
    pub fn default_20x20() -> Self {
        Self::new(DigitConfig::default())
    }

    /// Histogram dimension d = grid².
    pub fn dim(&self) -> usize {
        self.config.grid * self.config.grid
    }

    /// Grid side length.
    pub fn grid(&self) -> usize {
        self.config.grid
    }

    /// Render one sample of the given class.
    pub fn sample(&self, class: DigitClass, rng: &mut Rng) -> Sample {
        assert!(class.0 < 10, "digit classes are 0..10");
        let g = self.config.grid;
        let cfg = &self.config;

        // Random affine jitter around the glyph center (0.5, 0.5).
        let theta = rng.range_f64(-cfg.rotate, cfg.rotate);
        let scale = 1.0 + rng.range_f64(-cfg.scale, cfg.scale);
        let (tx, ty) = (
            rng.range_f64(-cfg.translate, cfg.translate),
            rng.range_f64(-cfg.translate, cfg.translate),
        );
        let (cos_t, sin_t) = (theta.cos(), theta.sin());
        let jitter = |x: F, y: F, rng: &mut Rng| -> (F, F) {
            let (xc, yc) = (x - 0.5, y - 0.5);
            let xr = scale * (cos_t * xc - sin_t * yc) + 0.5 + tx;
            let yr = scale * (sin_t * xc + cos_t * yc) + 0.5 + ty;
            (
                xr + rng.range_f64(-cfg.wobble, cfg.wobble),
                yr + rng.range_f64(-cfg.wobble, cfg.wobble),
            )
        };

        // Rasterize strokes with a Gaussian pen, sampling points densely
        // along each polyline segment.
        let mut img = vec![0.0; g * g];
        let sigma = cfg.pen_sigma.max(1e-3);
        // Work in pixel units: pen sigma in pixels.
        let sigma_px = sigma * g as F;
        let inv2s2 = 1.0 / (2.0 * sigma_px * sigma_px);
        // Pixels within 3 sigma of the pen center receive ink.
        let reach = (3.0 * sigma_px).ceil().max(1.0) as i64;
        for stroke in DIGIT_STROKES[class.0] {
            let pts: Vec<(F, F)> =
                stroke.iter().map(|&(x, y)| jitter(x, y, rng)).collect();
            for w in pts.windows(2) {
                let (x0, y0) = w[0];
                let (x1, y1) = w[1];
                let seg_len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
                let steps = ((seg_len * g as F * 2.0).ceil() as usize).max(1);
                for s in 0..=steps {
                    let t = s as F / steps as F;
                    let px = (x0 + t * (x1 - x0)) * g as F;
                    let py = (y0 + t * (y1 - y0)) * g as F;
                    let (ix, iy) = (px.round() as i64, py.round() as i64);
                    for dy in -reach..=reach {
                        for dx in -reach..=reach {
                            let (qx, qy) = (ix + dx, iy + dy);
                            if qx < 0 || qy < 0 || qx >= g as i64 || qy >= g as i64 {
                                continue;
                            }
                            let ddx = (qx as F + 0.5) - px;
                            let ddy = (qy as F + 0.5) - py;
                            let dist2 = ddx * ddx + ddy * ddy;
                            let ink = (-dist2 * inv2s2).exp() / steps as F;
                            img[(qy as usize) * g + qx as usize] += ink;
                        }
                    }
                }
            }
        }

        // Pixel noise proportional to the ink peak, then normalize.
        let peak = img.iter().cloned().fold(0.0, F::max).max(1e-12);
        for v in &mut img {
            *v += rng.f64() * cfg.pixel_noise * peak;
        }
        let histogram = Histogram::from_weights(&img)
            .expect("rendered digit has positive mass");
        Sample { histogram, label: class.0 }
    }

    /// Generate a balanced dataset of n samples (labels cycle 0..10).
    pub fn dataset(&self, n: usize, rng: &mut Rng) -> Vec<Sample> {
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            out.push(self.sample(DigitClass(i % 10), rng));
        }
        // Shuffle so folds don't align with the label cycle.
        rng.shuffle(&mut out);
        out
    }

    /// ASCII rendering (for docs/examples): rows of intensity glyphs.
    pub fn ascii(&self, h: &Histogram) -> String {
        let g = self.config.grid;
        let ramp = [' ', '.', ':', '+', '*', '#', '@'];
        let peak = h.values().iter().cloned().fold(0.0, F::max).max(1e-12);
        let mut s = String::with_capacity(g * (g + 1));
        for y in 0..g {
            for x in 0..g {
                let v = h.values()[y * g + x] / peak;
                let idx = ((v * (ramp.len() - 1) as F).round() as usize)
                    .min(ramp.len() - 1);
                s.push(ramp[idx]);
            }
            s.push('\n');
        }
        s
    }
}

/// Clustered histogram corpus generator — the retrieval subsystem's
/// synthetic workload (and the one shared by its bench, tests and the
/// serve_demo example, so the cluster recipe cannot drift between
/// them). Each cluster is a spiky Dirichlet prototype; each entry mixes
/// the prototype with fresh Dirichlet noise:
/// `entry = (1 − mix)·prototype + mix·noise`. Small `mix` gives the
/// near/far structure a bound cascade prunes on; `mix = 1.0`
/// degenerates to a fully unstructured corpus.
#[derive(Debug, Clone, Copy)]
pub struct ClusteredCorpus {
    /// Histogram dimension d.
    pub dim: usize,
    /// Number of cluster prototypes.
    pub clusters: usize,
    /// Entries generated per cluster.
    pub per_cluster: usize,
    /// Noise mixture weight in [0, 1].
    pub mix: F,
    /// Dirichlet α of the prototypes (< 1 ⇒ spiky, well-separated).
    pub proto_alpha: F,
    /// Dirichlet α of the per-entry noise.
    pub noise_alpha: F,
}

impl ClusteredCorpus {
    /// The standard recipe: Dirichlet(0.3) prototypes, Dirichlet(1.0)
    /// noise.
    pub fn new(dim: usize, clusters: usize, per_cluster: usize, mix: F) -> Self {
        Self { dim, clusters, per_cluster, mix, proto_alpha: 0.3, noise_alpha: 1.0 }
    }

    /// One prototype/noise mixture at an explicit mixing weight (also
    /// how queries "near" a prototype are drawn).
    pub fn mixture_at(&self, proto: &Histogram, mix: F, rng: &mut Rng) -> Histogram {
        let noise = Histogram::sample_dirichlet(proto.dim(), self.noise_alpha, rng);
        let w: Vec<F> = proto
            .values()
            .iter()
            .zip(noise.values())
            .map(|(a, b)| (1.0 - mix) * a + mix * b)
            .collect();
        Histogram::from_weights(&w).expect("mixture of histograms has positive mass")
    }

    /// Draw (corpus, prototypes): `clusters × per_cluster` entries in
    /// cluster-major order (entry i belongs to cluster i / per_cluster).
    pub fn generate(&self, rng: &mut Rng) -> (Vec<Histogram>, Vec<Histogram>) {
        let protos: Vec<Histogram> = (0..self.clusters)
            .map(|_| Histogram::sample_dirichlet(self.dim, self.proto_alpha, rng))
            .collect();
        let mut corpus = Vec::with_capacity(self.clusters * self.per_cluster);
        for p in &protos {
            for _ in 0..self.per_cluster {
                corpus.push(self.mixture_at(p, self.mix, rng));
            }
        }
        (corpus, protos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::ClassicalDistance;
    use crate::simplex::seeded_rng;

    #[test]
    fn clustered_corpus_shapes_and_structure() {
        let gen = ClusteredCorpus::new(16, 4, 5, 0.1);
        let mut rng = seeded_rng(9);
        let (corpus, protos) = gen.generate(&mut rng);
        assert_eq!(corpus.len(), 20);
        assert_eq!(protos.len(), 4);
        assert!(corpus.iter().all(|h| h.dim() == 16 && h.mass_error() < 1e-9));
        // At mix 0.1 an entry sits far closer (in TV) to its own
        // prototype than to the others' — the structure retrieval prunes
        // on.
        let tv = |a: &Histogram, b: &Histogram| -> F {
            0.5 * a
                .values()
                .iter()
                .zip(b.values())
                .map(|(x, y)| (x - y).abs())
                .sum::<F>()
        };
        for (i, h) in corpus.iter().enumerate() {
            let own = tv(h, &protos[i / 5]);
            let best_other = protos
                .iter()
                .enumerate()
                .filter(|(c, _)| *c != i / 5)
                .map(|(_, p)| tv(h, p))
                .fold(F::INFINITY, F::min);
            assert!(own < best_other, "entry {i}: own {own} vs other {best_other}");
        }
        // mixture_at at mix 1.0 ignores the prototype entirely (pure
        // noise), at 0.0 reproduces it.
        let exact = gen.mixture_at(&protos[0], 0.0, &mut rng);
        for (a, b) in exact.values().iter().zip(protos[0].values()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn samples_are_valid_histograms() {
        let gen = SyntheticDigits::default_20x20();
        let mut rng = seeded_rng(0);
        for class in 0..10 {
            let s = gen.sample(DigitClass(class), &mut rng);
            assert_eq!(s.histogram.dim(), 400);
            assert!(s.histogram.mass_error() < 1e-9);
            assert_eq!(s.label, class);
            // Ink should cover a nontrivial region.
            let support = s.histogram.support_size();
            assert!(support > 40, "class {class}: support {support}");
        }
    }

    #[test]
    fn dataset_is_balanced_and_shuffled() {
        let gen = SyntheticDigits::default_20x20();
        let mut rng = seeded_rng(1);
        let ds = gen.dataset(50, &mut rng);
        assert_eq!(ds.len(), 50);
        for c in 0..10 {
            assert_eq!(ds.iter().filter(|s| s.label == c).count(), 5);
        }
        let labels: Vec<usize> = ds.iter().map(|s| s.label).collect();
        assert_ne!(labels, (0..50).map(|i| i % 10).collect::<Vec<_>>());
    }

    #[test]
    fn within_class_closer_than_between_class() {
        // The geometric sanity check that makes classification possible:
        // average same-class distance < average cross-class distance.
        let gen = SyntheticDigits::default_20x20();
        let mut rng = seeded_rng(2);
        let per_class = 4;
        let samples: Vec<Sample> = (0..10)
            .flat_map(|c| {
                (0..per_class)
                    .map(|_| gen.sample(DigitClass(c), &mut rng))
                    .collect::<Vec<_>>()
            })
            .collect();
        let (mut within, mut wn) = (0.0, 0usize);
        let (mut between, mut bn) = (0.0, 0usize);
        for i in 0..samples.len() {
            for j in (i + 1)..samples.len() {
                let d = ClassicalDistance::TotalVariation
                    .eval(&samples[i].histogram, &samples[j].histogram);
                if samples[i].label == samples[j].label {
                    within += d;
                    wn += 1;
                } else {
                    between += d;
                    bn += 1;
                }
            }
        }
        let (within, between) = (within / wn as F, between / bn as F);
        assert!(
            within < between,
            "within {within} should be < between {between}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = SyntheticDigits::default_20x20();
        let a = gen.sample(DigitClass(3), &mut seeded_rng(9));
        let b = gen.sample(DigitClass(3), &mut seeded_rng(9));
        assert_eq!(a.histogram.values(), b.histogram.values());
    }

    #[test]
    fn small_grids_work() {
        let gen = SyntheticDigits::new(DigitConfig { grid: 8, ..Default::default() });
        let mut rng = seeded_rng(4);
        let s = gen.sample(DigitClass(7), &mut rng);
        assert_eq!(s.histogram.dim(), 64);
    }

    #[test]
    fn ascii_renders() {
        let gen = SyntheticDigits::default_20x20();
        let mut rng = seeded_rng(5);
        let s = gen.sample(DigitClass(0), &mut rng);
        let art = gen.ascii(&s.histogram);
        assert_eq!(art.lines().count(), 20);
        assert!(art.contains('@') || art.contains('#'));
    }
}
