//! Small self-contained utilities (offline-build substitutes for common
//! ecosystem crates): a JSON parser for the artifact manifest and a
//! micro-benchmark timing harness used by the `benches/` targets.

pub mod bench;
pub mod json;
