//! Small self-contained utilities (offline-build substitutes for common
//! ecosystem crates): a JSON parser for the artifact manifest, a
//! micro-benchmark timing harness used by the `benches/` targets, and the
//! shared log2 latency histogram behind every quantile gauge.

pub mod bench;
pub mod histogram;
pub mod json;

/// A duration in whole microseconds, saturating at `u64::MAX` — the one
/// clamp every latency/walltime gauge in the crate shares, so the
/// saturation semantics cannot drift per call site.
pub fn saturating_micros(d: std::time::Duration) -> u64 {
    d.as_micros().min(u64::MAX as u128) as u64
}
