//! Micro-benchmark harness (offline substitute for criterion).
//!
//! The `benches/` targets are `harness = false` binaries that use this
//! module to time closures with warm-up, repeat sampling, and robust
//! summary statistics (median + MAD), printing one row per case in a
//! stable machine-grepable format:
//!
//! ```text
//! bench <name> median_ns=… mad_ns=… samples=… [key=value …]
//! ```

use std::time::Instant;

/// Result of timing one case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub mean_ns: f64,
    pub samples: usize,
}

impl Timing {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_us(&self) -> f64 {
        self.median_ns / 1e3
    }
}

/// Benchmark runner with a global time budget per case.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// Warm-up executions before sampling.
    pub warmup: usize,
    /// Max sampling repetitions.
    pub max_samples: usize,
    /// Soft budget per case in seconds (sampling stops once exceeded).
    pub budget_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 2, max_samples: 25, budget_secs: 3.0 }
    }
}

impl Bench {
    /// Quick preset for expensive cases (e.g. exact EMD at large d).
    pub fn quick() -> Self {
        Self { warmup: 1, max_samples: 7, budget_secs: 10.0 }
    }

    /// Time `f`, which must return something observable (guards against
    /// dead-code elimination via `std::hint::black_box`).
    pub fn time<T>(&self, mut f: impl FnMut() -> T) -> Timing {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples_ns: Vec<f64> = Vec::with_capacity(self.max_samples);
        let started = Instant::now();
        while samples_ns.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if started.elapsed().as_secs_f64() > self.budget_secs && samples_ns.len() >= 3 {
                break;
            }
        }
        summarize(&samples_ns)
    }

    /// Time and print one row.
    pub fn report<T>(&self, name: &str, extra: &str, f: impl FnMut() -> T) -> Timing {
        let t = self.time(f);
        println!(
            "bench {name} median_ns={:.0} mad_ns={:.0} mean_ns={:.0} samples={} {extra}",
            t.median_ns, t.mad_ns, t.mean_ns, t.samples
        );
        t
    }
}

fn summarize(samples: &[f64]) -> Timing {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = v[v.len() / 2];
    let mut dev: Vec<f64> = v.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    Timing { median_ns: median, mad_ns: mad, mean_ns: mean, samples: v.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let b = Bench { warmup: 1, max_samples: 5, budget_secs: 1.0 };
        let fast = b.time(|| 1 + 1);
        let slow = b.time(|| {
            // black_box the bound so the loop cannot be constant-folded.
            let n = std::hint::black_box(200_000u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(std::hint::black_box(i) * i);
            }
            acc
        });
        assert!(fast.median_ns >= 0.0);
        assert!(slow.median_ns > fast.median_ns);
        assert!(slow.samples >= 3);
    }

    #[test]
    fn summary_statistics() {
        let t = summarize(&[1.0, 2.0, 100.0]);
        assert_eq!(t.median_ns, 2.0);
        assert_eq!(t.mad_ns, 1.0);
        assert!((t.mean_ns - 34.333).abs() < 0.01);
    }

    #[test]
    fn budget_caps_samples() {
        let b = Bench { warmup: 0, max_samples: 1000, budget_secs: 0.05 };
        let t = b.time(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(t.samples <= 5);
    }
}
