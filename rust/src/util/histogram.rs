//! Shared 32-bucket log2 latency histogram (PR 9).
//!
//! `coordinator/metrics.rs` grew three hand-rolled copies of the same
//! structure (query latency, certified interval width, and the PR 9 stage
//! histograms); this module dedupes them behind one unit-tested type with
//! the PR 7 *clamped* quantile semantics: a quantile answer is the upper
//! edge of the selected bucket, clamped to the largest value actually
//! observed, so a histogram fed a single 100µs sample reports p99 = 100µs
//! rather than the 128µs bucket edge.
//!
//! Bucket `i` covers values `v` with `floor(log2(max(v, 1))) == i`, with
//! everything at or above `2^31` clamped into the last bucket. Recording is
//! O(1) and allocation-free; the struct is plain-old-data and `Clone`.

/// Fixed 32-bucket log2 histogram over `u64` samples.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Log2Histogram {
    buckets: [u64; 32],
    count: u64,
    max: u64,
}

impl Log2Histogram {
    /// Empty histogram. Identical to `Default::default()`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for a sample: `floor(log2(max(v, 1)))`, clamped to 31.
    #[inline]
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.max(1).leading_zeros() as usize - 1).min(31)
    }

    /// Record one sample.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.max = self.max.max(v);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest sample seen (0 when empty). Quantiles clamp to this.
    pub fn observed_max(&self) -> u64 {
        self.max
    }

    /// Raw bucket counts (for callers that fold histograms into reports).
    pub fn buckets(&self) -> &[u64; 32] {
        &self.buckets
    }

    /// Index of the bucket holding the `q`-quantile sample, or `None` when
    /// the histogram is empty or `q` exceeds 1.0 past the last bucket.
    ///
    /// The target rank is `ceil(q * count)`, floored at rank 1, matching
    /// the PR 7 walk: the first bucket whose cumulative count reaches the
    /// rank wins. The rank-1 floor keeps `q = 0.0` honest: without it the
    /// target rank is 0 and the very first bucket satisfies `seen >= 0`
    /// even when bucket 0 is empty, so `quantile(0.0)` would report bucket
    /// 0's edge rather than the bucket actually holding the smallest
    /// sample.
    pub fn quantile_bucket(&self, q: f64) -> Option<usize> {
        if self.count == 0 {
            return None;
        }
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Some(i);
            }
        }
        None
    }

    /// Fold another histogram into this one: bucket-wise count add, total
    /// count sum, max of observed maxima. Used by the sliding-window
    /// rollups (PR 10) to answer "over the last minute" from a ring of
    /// per-window histograms, and by the stage-row fold in `trace`.
    pub fn merge(&mut self, other: &Log2Histogram) {
        for (dst, src) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *dst += *src;
        }
        self.count += other.count;
        self.max = self.max.max(other.max);
    }

    /// Clamped `q`-quantile in the sample's own units: the upper edge of
    /// the selected bucket (`2^(i+1)`), clamped to the observed maximum.
    /// Returns 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        match self.quantile_bucket(q) {
            Some(i) => (1u64 << (i + 1)).min(self.max),
            None => self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_placement_is_floor_log2() {
        assert_eq!(Log2Histogram::bucket_of(0), 0);
        assert_eq!(Log2Histogram::bucket_of(1), 0);
        assert_eq!(Log2Histogram::bucket_of(2), 1);
        assert_eq!(Log2Histogram::bucket_of(3), 1);
        assert_eq!(Log2Histogram::bucket_of(4), 2);
        assert_eq!(Log2Histogram::bucket_of(1023), 9);
        assert_eq!(Log2Histogram::bucket_of(1024), 10);
        assert_eq!(Log2Histogram::bucket_of(u64::MAX), 31);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = Log2Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.observed_max(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile_bucket(0.5), None);
    }

    #[test]
    fn single_sample_quantile_clamps_to_observed_max() {
        // PR 7 semantics: one 100µs sample must report 100, not the 128
        // bucket edge.
        let mut h = Log2Histogram::new();
        h.record(100);
        assert_eq!(h.quantile(0.5), 100);
        assert_eq!(h.quantile(0.99), 100);
    }

    #[test]
    fn quantile_walks_cumulative_counts() {
        let mut h = Log2Histogram::new();
        // 90 samples at 100µs (bucket 6), 10 at 1000µs (bucket 9).
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(1000);
        }
        // p50 lands in bucket 6: edge 128, observed max 1000 -> 128.
        assert_eq!(h.quantile(0.5), 128);
        // p99 lands in bucket 9: edge 1024, clamped to max 1000.
        assert_eq!(h.quantile(0.99), 1000);
        assert_eq!(h.observed_max(), 1000);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn quantile_bucket_exposes_raw_index_for_unit_mapping() {
        // metrics.rs maps width buckets back into seconds through the ppb
        // encoding; it needs the raw bucket index, not the u64 edge.
        let mut h = Log2Histogram::new();
        h.record(100); // ppb value, bucket 6
        assert_eq!(h.quantile_bucket(0.5), Some(6));
    }

    #[test]
    fn quantile_zero_reports_smallest_occupied_bucket() {
        // Regression: rank ceil(0.0 * count) == 0 used to let the empty
        // bucket 0 satisfy `seen >= target`, reporting edge 2 for a
        // histogram whose smallest sample lives in bucket 6.
        let mut h = Log2Histogram::new();
        h.record(100); // bucket 6
        h.record(1000); // bucket 9
        assert_eq!(h.quantile_bucket(0.0), Some(6));
        assert_eq!(h.quantile(0.0), 128);
        // Still None when empty.
        assert_eq!(Log2Histogram::new().quantile_bucket(0.0), None);
    }

    #[test]
    fn merge_adds_buckets_counts_and_maxes() {
        let mut a = Log2Histogram::new();
        a.record(100);
        a.record(3);
        let mut b = Log2Histogram::new();
        b.record(1000);
        b.record(100);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.observed_max(), 1000);
        assert_eq!(a.buckets()[Log2Histogram::bucket_of(100)], 2);
        assert_eq!(a.buckets()[Log2Histogram::bucket_of(3)], 1);
        assert_eq!(a.buckets()[Log2Histogram::bucket_of(1000)], 1);
        // Quantiles answer over the merged population.
        assert_eq!(a.quantile(1.0), 1000);
    }

    #[test]
    fn merge_empty_into_empty_stays_empty() {
        let mut a = Log2Histogram::new();
        a.merge(&Log2Histogram::new());
        assert!(a.is_empty());
        assert_eq!(a.observed_max(), 0);
        assert_eq!(a.quantile(0.5), 0);
    }

    #[test]
    fn merge_preserves_bucket_31_clamp() {
        let mut a = Log2Histogram::new();
        a.record(u64::MAX); // clamped into bucket 31
        let mut b = Log2Histogram::new();
        b.record(u64::MAX - 1); // also bucket 31
        a.merge(&b);
        assert_eq!(a.buckets()[31], 2);
        assert_eq!(a.count(), 2);
        assert_eq!(a.observed_max(), u64::MAX);
        // Bucket 31's nominal edge is 1<<32; with samples above it the
        // min-with-max clamp keeps the edge (PR 7 semantics preserved
        // across merge).
        assert_eq!(a.quantile(1.0), 1u64 << 32);
    }

    #[test]
    fn max_tracks_largest_sample_across_buckets() {
        let mut h = Log2Histogram::new();
        h.record(3);
        h.record(300);
        h.record(7);
        assert_eq!(h.observed_max(), 300);
        // q=1.0 rank == count: last occupied bucket, clamped to max.
        assert_eq!(h.quantile(1.0), 300);
    }
}
