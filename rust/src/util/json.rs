//! Minimal recursive-descent JSON parser.
//!
//! Parses the artifact manifest written by `python/compile/aot.py` (and
//! any other small JSON the harnesses need) without an external
//! dependency. Supports the full JSON grammar except surrogate-pair
//! escapes beyond the BMP (sufficient for machine-generated manifests);
//! numbers are parsed as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Number(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Number(x) => write!(f, "{x}"),
            Json::String(s) => {
                write!(f, "\"")?;
                for ch in s.chars() {
                    match ch {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::String(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError { offset: self.pos, message: message.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::String),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("surrogate \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Number(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::String("a\nb".into())
        );
    }

    #[test]
    fn nested_document() {
        let doc = r#" {"version": 1, "variants": [
            {"name": "x", "d": 16, "flavor": "xla", "ok": true},
            {"name": "y", "d": 400, "flavor": "pallas", "ok": false}
        ], "note": null} "#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let vars = v.get("variants").unwrap().as_array().unwrap();
        assert_eq!(vars.len(), 2);
        assert_eq!(vars[1].get("d").unwrap().as_usize(), Some(400));
        assert_eq!(vars[0].get("flavor").unwrap().as_str(), Some("xla"));
        assert_eq!(vars[0].get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("note"), Some(&Json::Null));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn error_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert_eq!(e.offset, 6);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[1] junk").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let doc = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn real_manifest_shape() {
        let doc = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        );
        if let Ok(doc) = doc {
            // When artifacts exist, the manifest must parse.
            let v = Json::parse(&doc).unwrap();
            assert!(v.get("variants").unwrap().as_array().unwrap().len() > 0);
        }
    }
}
