//! Per-tenant sliding-window rollups and the SLO burn-rate monitor.
//!
//! When telemetry is on, every served query/retrieval additionally feeds
//! per-tenant windowed instruments (latency, deadline misses, certified
//! widths, search time, recall). An optional [`SloPolicy`] layers
//! machine-checkable objectives on top: per evaluation the monitor
//! computes a **fast burn rate** (bad-event rate over the current +
//! previous window, normalized by the policy's error budget) and a
//! **slow burn rate** (over the whole ring), exports both as gauges, and
//! **arms** a tenant whose burn crosses the thresholds — armed tenants'
//! batches are shed to the policy's iteration cap by the engine (the
//! PR 6 `shed_cap` path, now policy-driven instead of backlog-age-only).
//!
//! Burn-rate semantics follow the standard SRE construction: a burn of
//! 1.0 means the tenant is consuming its error budget exactly as fast as
//! the policy allows; the default fast threshold 8 catches "budget gone
//! within the ring", the slow threshold 2 catches sustained slow leaks.
//! Recall-floor and interval-width breaches export as gauges but never
//! arm shedding — shedding *widens* intervals and cannot help either.

use std::collections::BTreeMap;
use std::fmt;
use std::time::Duration;

use super::registry::{CounterId, GaugeId, HistogramId, Labels, Registry};
use crate::trace::Tenant;
use crate::F;

/// Declarative per-tenant service-level objectives. One policy applies
/// to every tenant (per-tenant policies would just be a map here).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloPolicy {
    /// Latency objective: a query slower than this is a *bad event* even
    /// if it carried no deadline.
    pub p99_latency: Duration,
    /// Error budget: the fraction of a tenant's queries allowed to be
    /// bad (deadline-missed or over `p99_latency`) per window. Burn rate
    /// = bad_fraction / budget.
    pub deadline_miss_budget: f64,
    /// Windowed probed recall below this floor raises the recall-breach
    /// gauge for the corpus tenant (never arms shedding).
    pub recall_floor: f64,
    /// Windowed p99 certified interval width above this ceiling raises
    /// the width-breach gauge (never arms shedding). `F::INFINITY`
    /// disables the check.
    pub interval_width_ceiling: F,
    /// Fast-burn alarm threshold over the current + previous window.
    pub fast_burn: f64,
    /// Slow-burn alarm threshold over the whole ring.
    pub slow_burn: f64,
    /// Iteration cap applied to an armed tenant's batches. `None` makes
    /// the monitor alert-only.
    pub shed_iterations: Option<usize>,
}

impl Default for SloPolicy {
    fn default() -> Self {
        Self {
            p99_latency: Duration::from_millis(50),
            deadline_miss_budget: 0.01,
            recall_floor: 0.0,
            interval_width_ceiling: F::INFINITY,
            fast_burn: 8.0,
            slow_burn: 2.0,
            shed_iterations: Some(32),
        }
    }
}

impl SloPolicy {
    pub fn validate(&self) -> Result<(), String> {
        if self.p99_latency.is_zero() {
            return Err("slo.p99_latency must be nonzero".into());
        }
        if !(self.deadline_miss_budget > 0.0 && self.deadline_miss_budget <= 1.0) {
            return Err(format!(
                "slo.deadline_miss_budget must be in (0, 1] (got {})",
                self.deadline_miss_budget
            ));
        }
        if !(0.0..=1.0).contains(&self.recall_floor) {
            return Err(format!(
                "slo.recall_floor must be in [0, 1] (got {})",
                self.recall_floor
            ));
        }
        if !(self.interval_width_ceiling > 0.0) {
            return Err(format!(
                "slo.interval_width_ceiling must be positive (got {})",
                self.interval_width_ceiling
            ));
        }
        if !(self.fast_burn > 0.0 && self.fast_burn.is_finite()) {
            return Err(format!("slo.fast_burn must be positive and finite (got {})", self.fast_burn));
        }
        if !(self.slow_burn > 0.0 && self.slow_burn.is_finite()) {
            return Err(format!("slo.slow_burn must be positive and finite (got {})", self.slow_burn));
        }
        if self.shed_iterations == Some(0) {
            return Err("slo.shed_iterations must be >= 1 when set".into());
        }
        Ok(())
    }
}

/// Windowed instruments for one metric (distance-query) tenant.
#[derive(Debug, Clone, Copy)]
struct MetricTenant {
    queries: CounterId,
    misses: CounterId,
    bad: CounterId,
    latency: HistogramId,
    width: HistogramId,
    fast_gauge: GaugeId,
    slow_gauge: GaugeId,
    armed_gauge: GaugeId,
    width_breach: GaugeId,
    armed: bool,
}

/// Windowed instruments for one corpus (retrieval) tenant.
#[derive(Debug, Clone, Copy)]
struct CorpusTenant {
    searches: CounterId,
    search_us: HistogramId,
    recall_matched: CounterId,
    recall_expected: CounterId,
    recall_breach: GaugeId,
}

/// The monitor: exists exactly when telemetry is on; the policy inside
/// is optional (instruments + report without alerting).
#[derive(Debug, Clone)]
pub struct SloMonitor {
    policy: Option<SloPolicy>,
    metrics: BTreeMap<u32, MetricTenant>,
    corpora: BTreeMap<u32, CorpusTenant>,
}

impl SloMonitor {
    pub fn new(policy: Option<SloPolicy>) -> Self {
        Self { policy, metrics: BTreeMap::new(), corpora: BTreeMap::new() }
    }

    pub fn policy(&self) -> Option<&SloPolicy> {
        self.policy.as_ref()
    }

    fn metric_tenant(&mut self, reg: &mut Registry, tenant: u32) -> MetricTenant {
        if let Some(t) = self.metrics.get(&tenant) {
            return *t;
        }
        let labels = Labels::tenant(Tenant::Metric(tenant));
        let t = MetricTenant {
            queries: reg.counter(
                "sinkhorn_tenant_queries_total",
                "Distance queries served, per metric tenant",
                labels,
            ),
            misses: reg.counter(
                "sinkhorn_tenant_deadline_misses_total",
                "Queries answered after their own deadline, per metric tenant",
                labels,
            ),
            bad: reg.counter(
                "sinkhorn_tenant_slo_bad_total",
                "SLO bad events (deadline miss or latency over objective), per metric tenant",
                labels,
            ),
            latency: reg.histogram(
                "sinkhorn_tenant_latency_us",
                "Query latency in microseconds, per metric tenant",
                labels,
            ),
            width: reg.histogram(
                "sinkhorn_tenant_interval_width_ppb",
                "Certified interval width in parts-per-billion, per metric tenant",
                labels,
            ),
            fast_gauge: reg.gauge(
                "sinkhorn_slo_fast_burn",
                "Fast burn rate (bad rate over current+previous window / error budget)",
                labels,
            ),
            slow_gauge: reg.gauge(
                "sinkhorn_slo_slow_burn",
                "Slow burn rate (bad rate over the whole window ring / error budget)",
                labels,
            ),
            armed_gauge: reg.gauge(
                "sinkhorn_slo_armed",
                "1 when the tenant's latency SLO burn has armed policy-driven shedding",
                labels,
            ),
            width_breach: reg.gauge(
                "sinkhorn_slo_width_breach",
                "1 when the tenant's windowed p99 certified interval width exceeds the ceiling",
                labels,
            ),
            armed: false,
        };
        self.metrics.insert(tenant, t);
        t
    }

    fn corpus_tenant(&mut self, reg: &mut Registry, corpus: u32) -> CorpusTenant {
        if let Some(t) = self.corpora.get(&corpus) {
            return *t;
        }
        let labels = Labels::tenant(Tenant::Corpus(corpus));
        let t = CorpusTenant {
            searches: reg.counter(
                "sinkhorn_tenant_searches_total",
                "Off-thread searches completed, per corpus tenant",
                labels,
            ),
            search_us: reg.histogram(
                "sinkhorn_tenant_search_us",
                "Pure search walltime in microseconds, per corpus tenant",
                labels,
            ),
            recall_matched: reg.counter(
                "sinkhorn_tenant_recall_matched_total",
                "Probe-confirmed top-k entries, per corpus tenant",
                labels,
            ),
            recall_expected: reg.counter(
                "sinkhorn_tenant_recall_expected_total",
                "Probe-compared top-k entries, per corpus tenant",
                labels,
            ),
            recall_breach: reg.gauge(
                "sinkhorn_slo_recall_breach",
                "1 when the tenant's windowed probed recall is below the policy floor",
                labels,
            ),
        };
        self.corpora.insert(corpus, t);
        t
    }

    /// Record one served query. Returns nothing; the bad-event decision
    /// (missed deadline OR latency over the policy objective) happens
    /// here so it is counted in the same window the query landed in.
    pub fn on_query(&mut self, reg: &mut Registry, tenant: u32, latency_us: u64, missed: bool) {
        let t = self.metric_tenant(reg, tenant);
        reg.add(t.queries, 1);
        reg.observe(t.latency, latency_us);
        if missed {
            reg.add(t.misses, 1);
        }
        let over = match self.policy {
            Some(p) => latency_us as u128 > p.p99_latency.as_micros(),
            None => false,
        };
        if missed || over {
            reg.add(t.bad, 1);
        }
    }

    /// Record one certified outcome's interval width (ppb-quantized).
    pub fn on_outcome(&mut self, reg: &mut Registry, tenant: u32, width_ppb: u64) {
        let t = self.metric_tenant(reg, tenant);
        reg.observe(t.width, width_ppb);
    }

    /// Record one completed off-thread search (and its optional recall
    /// probe) for a corpus tenant.
    pub fn on_search(
        &mut self,
        reg: &mut Registry,
        corpus: u32,
        search_us: u64,
        probe: Option<(u64, u64)>,
    ) {
        let t = self.corpus_tenant(reg, corpus);
        reg.add(t.searches, 1);
        reg.observe(t.search_us, search_us);
        if let Some((matched, expected)) = probe {
            reg.add(t.recall_matched, matched);
            reg.add(t.recall_expected, expected);
        }
    }

    /// Evaluate every tenant against the policy: refresh the burn-rate
    /// and breach gauges and the armed set. Cheap — O(tenants × ring) —
    /// and idempotent; the engine calls it once per message-loop turn.
    pub fn evaluate(&mut self, reg: &mut Registry) {
        let Some(policy) = self.policy else { return };
        for t in self.metrics.values_mut() {
            let fast_bad = reg.counter_recent(t.bad, 2);
            let fast_total = reg.counter_recent(t.queries, 2);
            let slow_bad = reg.counter_windowed(t.bad);
            let slow_total = reg.counter_windowed(t.queries);
            let fast = burn_rate(fast_bad, fast_total, policy.deadline_miss_budget);
            let slow = burn_rate(slow_bad, slow_total, policy.deadline_miss_budget);
            t.armed = fast >= policy.fast_burn || slow >= policy.slow_burn;
            reg.set(t.fast_gauge, fast);
            reg.set(t.slow_gauge, slow);
            reg.set(t.armed_gauge, if t.armed { 1.0 } else { 0.0 });
            let width_p99 = reg.histogram_windowed(t.width).quantile(0.99) as F * 1e-9;
            let breach = policy.interval_width_ceiling.is_finite()
                && width_p99 > policy.interval_width_ceiling;
            reg.set(t.width_breach, if breach { 1.0 } else { 0.0 });
        }
        for t in self.corpora.values() {
            let matched = reg.counter_windowed(t.recall_matched);
            let expected = reg.counter_windowed(t.recall_expected);
            let breach = expected > 0 && (matched as f64 / expected as f64) < policy.recall_floor;
            reg.set(t.recall_breach, if breach { 1.0 } else { 0.0 });
        }
    }

    /// The iteration cap to shed an armed tenant's batch to, or `None`
    /// when the tenant is compliant (or the monitor is alert-only).
    pub fn shed_cap(&self, tenant: u32) -> Option<usize> {
        let policy = self.policy.as_ref()?;
        let cap = policy.shed_iterations?;
        self.metrics.get(&tenant).filter(|t| t.armed).map(|_| cap)
    }

    /// Build the windowed per-tenant report.
    pub fn report(&self, reg: &Registry) -> TelemetryReport {
        let policy = self.policy;
        let tenants = self
            .metrics
            .iter()
            .map(|(&id, t)| {
                let queries = reg.counter_windowed(t.queries);
                let misses = reg.counter_windowed(t.misses);
                let bad = reg.counter_windowed(t.bad);
                let lat = reg.histogram_windowed(t.latency);
                let width = reg.histogram_windowed(t.width);
                TenantSlo {
                    tenant: Tenant::Metric(id).label(),
                    queries,
                    deadline_misses: misses,
                    miss_rate: rate(misses, queries),
                    bad_rate: rate(bad, queries),
                    p50_latency_us: lat.quantile(0.5),
                    p99_latency_us: lat.quantile(0.99),
                    interval_width_p99: width.quantile(0.99) as F * 1e-9,
                    fast_burn: reg.gauge_value(t.fast_gauge),
                    slow_burn: reg.gauge_value(t.slow_gauge),
                    armed: t.armed,
                }
            })
            .collect();
        let corpora = self
            .corpora
            .iter()
            .map(|(&id, t)| {
                let searches = reg.counter_windowed(t.searches);
                let matched = reg.counter_windowed(t.recall_matched);
                let expected = reg.counter_windowed(t.recall_expected);
                CorpusSlo {
                    tenant: Tenant::Corpus(id).label(),
                    searches,
                    p99_search_us: reg.histogram_windowed(t.search_us).quantile(0.99),
                    recall: if expected == 0 { 1.0 } else { matched as f64 / expected as f64 },
                    recall_breach: reg.gauge_value(t.recall_breach) > 0.5,
                }
            })
            .collect();
        TelemetryReport { windows: reg.window_count(), policy, tenants, corpora }
    }
}

fn rate(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

fn burn_rate(bad: u64, total: u64, budget: f64) -> f64 {
    if total == 0 || budget <= 0.0 {
        0.0
    } else {
        (bad as f64 / total as f64) / budget
    }
}

/// One metric tenant's windowed SLO status.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSlo {
    pub tenant: String,
    pub queries: u64,
    pub deadline_misses: u64,
    pub miss_rate: f64,
    pub bad_rate: f64,
    pub p50_latency_us: u64,
    pub p99_latency_us: u64,
    pub interval_width_p99: F,
    pub fast_burn: f64,
    pub slow_burn: f64,
    pub armed: bool,
}

/// One corpus tenant's windowed retrieval status.
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusSlo {
    pub tenant: String,
    pub searches: u64,
    pub p99_search_us: u64,
    pub recall: f64,
    pub recall_breach: bool,
}

/// The windowed per-tenant SLO report ("over the last minute" view).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryReport {
    /// Ring size the rollups cover.
    pub windows: usize,
    /// The active policy (None = instruments only, no alerting).
    pub policy: Option<SloPolicy>,
    pub tenants: Vec<TenantSlo>,
    pub corpora: Vec<CorpusSlo>,
}

impl fmt::Display for TelemetryReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "slo_window(n={})", self.windows)?;
        for t in &self.tenants {
            write!(
                f,
                " {}(q={} miss={} miss_rate={:.3} lat_us(p50~{}, p99~{}) \
                 burn(fast={:.2}, slow={:.2}){})",
                t.tenant,
                t.queries,
                t.deadline_misses,
                t.miss_rate,
                t.p50_latency_us,
                t.p99_latency_us,
                t.fast_burn,
                t.slow_burn,
                if t.armed { " ARMED" } else { "" },
            )?;
        }
        for c in &self.corpora {
            write!(
                f,
                " {}(s={} search_p99_us~{} recall={:.3}{})",
                c.tenant,
                c.searches,
                c.p99_search_us,
                c.recall,
                if c.recall_breach { " BREACH" } else { "" },
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn windowed_registry() -> Registry {
        Registry::new(Some((Duration::from_secs(60), 4)))
    }

    #[test]
    fn policy_validation_names_the_knob() {
        SloPolicy::default().validate().unwrap();
        let base = SloPolicy::default();
        for (policy, knob) in [
            (SloPolicy { p99_latency: Duration::ZERO, ..base }, "p99_latency"),
            (SloPolicy { deadline_miss_budget: 0.0, ..base }, "deadline_miss_budget"),
            (SloPolicy { deadline_miss_budget: 1.5, ..base }, "deadline_miss_budget"),
            (SloPolicy { recall_floor: -0.1, ..base }, "recall_floor"),
            (SloPolicy { interval_width_ceiling: 0.0, ..base }, "interval_width_ceiling"),
            (SloPolicy { fast_burn: 0.0, ..base }, "fast_burn"),
            (SloPolicy { slow_burn: f64::NAN, ..base }, "slow_burn"),
            (SloPolicy { shed_iterations: Some(0), ..base }, "shed_iterations"),
        ] {
            let err = policy.validate().unwrap_err();
            assert!(err.contains(knob), "expected {knob} in: {err}");
        }
    }

    #[test]
    fn breaching_tenant_arms_while_compliant_tenant_stays_clear() {
        let mut reg = windowed_registry();
        let mut mon = SloMonitor::new(Some(SloPolicy {
            p99_latency: Duration::from_millis(10),
            deadline_miss_budget: 0.01,
            ..SloPolicy::default()
        }));
        // Tenant 0 misses every deadline; tenant 1 is fast and clean.
        for _ in 0..20 {
            mon.on_query(&mut reg, 0, 50_000, true);
            mon.on_query(&mut reg, 1, 100, false);
        }
        mon.evaluate(&mut reg);
        assert_eq!(mon.shed_cap(0), Some(SloPolicy::default().shed_iterations.unwrap()));
        assert_eq!(mon.shed_cap(1), None);
        let report = mon.report(&reg);
        let t0 = report.tenants.iter().find(|t| t.tenant == "m0").unwrap();
        let t1 = report.tenants.iter().find(|t| t.tenant == "m1").unwrap();
        assert!(t0.armed && t0.fast_burn >= 8.0, "{t0:?}");
        assert!((t0.miss_rate - 1.0).abs() < 1e-12);
        assert!(!t1.armed && t1.fast_burn == 0.0, "{t1:?}");
        assert!(report.to_string().contains("ARMED"));
    }

    #[test]
    fn slow_latency_without_deadlines_still_burns() {
        // Bad events are not just deadline misses: sustained latency over
        // the objective burns the budget too.
        let mut reg = windowed_registry();
        let mut mon = SloMonitor::new(Some(SloPolicy {
            p99_latency: Duration::from_micros(100),
            ..SloPolicy::default()
        }));
        for _ in 0..10 {
            mon.on_query(&mut reg, 3, 10_000, false);
        }
        mon.evaluate(&mut reg);
        assert!(mon.shed_cap(3).is_some());
    }

    #[test]
    fn alert_only_policy_never_sheds() {
        let mut reg = windowed_registry();
        let mut mon = SloMonitor::new(Some(SloPolicy {
            p99_latency: Duration::from_micros(1),
            shed_iterations: None,
            ..SloPolicy::default()
        }));
        for _ in 0..10 {
            mon.on_query(&mut reg, 0, 1000, true);
        }
        mon.evaluate(&mut reg);
        assert_eq!(mon.shed_cap(0), None);
        let report = mon.report(&reg);
        assert!(report.tenants[0].armed, "still alerts");
    }

    #[test]
    fn disarm_after_the_window_slides_clean() {
        let mut reg = Registry::new(Some((Duration::from_millis(20), 3)));
        let mut mon = SloMonitor::new(Some(SloPolicy {
            p99_latency: Duration::from_micros(10),
            ..SloPolicy::default()
        }));
        for _ in 0..10 {
            mon.on_query(&mut reg, 0, 1000, true);
        }
        mon.evaluate(&mut reg);
        assert!(mon.shed_cap(0).is_some(), "armed under load");
        std::thread::sleep(Duration::from_millis(90));
        mon.evaluate(&mut reg);
        assert_eq!(mon.shed_cap(0), None, "bad events aged out of the ring");
        let report = mon.report(&reg);
        assert_eq!(report.tenants[0].queries, 0, "windowed view decayed");
        assert_eq!(report.tenants[0].deadline_misses, 0);
    }

    #[test]
    fn recall_floor_breach_is_gauge_only() {
        let mut reg = windowed_registry();
        let mut mon = SloMonitor::new(Some(SloPolicy {
            recall_floor: 0.9,
            ..SloPolicy::default()
        }));
        mon.on_search(&mut reg, 2, 500, Some((4, 10)));
        mon.evaluate(&mut reg);
        let report = mon.report(&reg);
        let c = report.corpora.iter().find(|c| c.tenant == "c2").unwrap();
        assert!((c.recall - 0.4).abs() < 1e-12);
        assert!(c.recall_breach);
        assert_eq!(mon.shed_cap(2), None, "recall breaches never arm shedding");
        assert!(report.to_string().contains("BREACH"));
    }
}
