//! Prometheus text-exposition (v0.0.4) rendering — and a hand-written
//! line parser used by the round-trip tests.
//!
//! Families render as `# HELP` / `# TYPE` headers followed by one sample
//! line per (labels, value). Counters and gauges are single lines;
//! histograms render the cumulative `_bucket{le="..."}` series (one line
//! per occupied log2 bucket prefix, then `+Inf`), `_sum` and `_count`.
//!
//! Log2 buckets map to exact integer upper bounds: bucket `i` holds
//! samples in `[2^i, 2^{i+1})`, so `le = 2^{i+1} - 1` is inclusive-exact
//! for integer samples. Bucket 31 is the clamp bucket (everything
//! ≥ 2^31, unbounded above), so it folds into `+Inf` rather than lying
//! with a finite bound.

use crate::util::histogram::Log2Histogram;

/// Prometheus metric kind, as rendered into `# TYPE`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PromKind {
    Counter,
    Gauge,
    Histogram,
}

impl PromKind {
    fn name(self) -> &'static str {
        match self {
            PromKind::Counter => "counter",
            PromKind::Gauge => "gauge",
            PromKind::Histogram => "histogram",
        }
    }
}

/// One sample value.
#[derive(Debug, Clone, PartialEq)]
pub enum PromValue {
    Counter(u64),
    Gauge(f64),
    /// Cumulative-bucket histogram: `(upper_bound, cumulative_count)`
    /// pairs in ascending bound order (the `+Inf` bucket is implicit —
    /// it always equals `count`).
    Histogram {
        buckets: Vec<(u64, u64)>,
        sum: u128,
        count: u64,
    },
}

impl PromValue {
    /// Convert a [`Log2Histogram`] into cumulative `le` buckets. Emits
    /// one bucket per index up to the highest occupied finite bucket
    /// (bucket 31, the clamp bucket, folds into `+Inf`).
    pub fn histogram(h: &Log2Histogram, sum: u128) -> Self {
        let mut buckets = Vec::new();
        let top = h
            .buckets()
            .iter()
            .enumerate()
            .rev()
            .find(|(_, &n)| n > 0)
            .map(|(i, _)| i.min(30))
            .unwrap_or(0);
        let mut cum = 0u64;
        if !h.is_empty() {
            for (i, &n) in h.buckets().iter().enumerate().take(top + 1) {
                cum += n;
                buckets.push(((1u64 << (i + 1)) - 1, cum));
            }
        }
        PromValue::Histogram { buckets, sum, count: h.count() }
    }
}

/// One sample: resolved label pairs plus the value.
#[derive(Debug, Clone, PartialEq)]
pub struct PromSample {
    pub labels: Vec<(&'static str, String)>,
    pub value: PromValue,
}

/// One family: a named group of samples sharing help text and kind.
#[derive(Debug, Clone, PartialEq)]
pub struct PromFamily {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: PromKind,
    pub samples: Vec<PromSample>,
}

/// The Content-Type the scrape server answers `/metrics` with.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Escape a label value per the exposition format (`\\`, `\"`, `\n`).
fn escape_label(v: &str, out: &mut String) {
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

/// Escape help text (`\\` and `\n` only — quotes are legal there).
fn escape_help(v: &str, out: &mut String) {
    for ch in v.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn render_labels(out: &mut String, labels: &[(&'static str, String)], extra: Option<(&str, &str)>) {
    if labels.is_empty() && extra.is_none() {
        return;
    }
    out.push('{');
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(k);
        out.push_str("=\"");
        escape_label(v, out);
        out.push('"');
    }
    if let Some((k, v)) = extra {
        if !first {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        escape_label(v, out);
        out.push('"');
    }
    out.push('}');
}

fn render_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".into()
    } else if v == f64::INFINITY {
        "+Inf".into()
    } else if v == f64::NEG_INFINITY {
        "-Inf".into()
    } else {
        format!("{v}")
    }
}

/// Render families into the v0.0.4 text exposition format. Families are
/// emitted in the order given (the registry already sorts by name); each
/// gets exactly one `# HELP` + `# TYPE` header.
pub fn render_prometheus(families: &[PromFamily]) -> String {
    let mut out = String::new();
    for fam in families {
        out.push_str("# HELP ");
        out.push_str(fam.name);
        out.push(' ');
        escape_help(fam.help, &mut out);
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(fam.name);
        out.push(' ');
        out.push_str(fam.kind.name());
        out.push('\n');
        for sample in &fam.samples {
            match &sample.value {
                PromValue::Counter(v) => {
                    out.push_str(fam.name);
                    render_labels(&mut out, &sample.labels, None);
                    out.push(' ');
                    out.push_str(&v.to_string());
                    out.push('\n');
                }
                PromValue::Gauge(v) => {
                    out.push_str(fam.name);
                    render_labels(&mut out, &sample.labels, None);
                    out.push(' ');
                    out.push_str(&render_f64(*v));
                    out.push('\n');
                }
                PromValue::Histogram { buckets, sum, count } => {
                    for (le, cum) in buckets {
                        out.push_str(fam.name);
                        out.push_str("_bucket");
                        render_labels(&mut out, &sample.labels, Some(("le", &le.to_string())));
                        out.push(' ');
                        out.push_str(&cum.to_string());
                        out.push('\n');
                    }
                    out.push_str(fam.name);
                    out.push_str("_bucket");
                    render_labels(&mut out, &sample.labels, Some(("le", "+Inf")));
                    out.push(' ');
                    out.push_str(&count.to_string());
                    out.push('\n');
                    out.push_str(fam.name);
                    out.push_str("_sum");
                    render_labels(&mut out, &sample.labels, None);
                    out.push(' ');
                    out.push_str(&sum.to_string());
                    out.push('\n');
                    out.push_str(fam.name);
                    out.push_str("_count");
                    render_labels(&mut out, &sample.labels, None);
                    out.push(' ');
                    out.push_str(&count.to_string());
                    out.push('\n');
                }
            }
        }
    }
    out
}

/// One parsed exposition line (see [`parse_exposition`]).
#[derive(Debug, Clone, PartialEq)]
pub enum PromLine {
    Help { name: String, help: String },
    Type { name: String, kind: String },
    /// `name{labels} value` — labels unescaped, in file order.
    Sample { name: String, labels: Vec<(String, String)>, value: f64 },
}

/// Hand-written parser for the v0.0.4 text format — the round-trip
/// oracle for [`render_prometheus`] and the assertion helper the e2e
/// scrape tests use. Returns `Err` with the offending line on any
/// malformed input.
pub fn parse_exposition(text: &str) -> Result<Vec<PromLine>, String> {
    let mut out = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest
                .split_once(' ')
                .ok_or_else(|| format!("malformed HELP line: {line}"))?;
            out.push(PromLine::Help {
                name: name.to_string(),
                help: unescape(help, false)?,
            });
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest
                .split_once(' ')
                .ok_or_else(|| format!("malformed TYPE line: {line}"))?;
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("unknown TYPE kind: {line}"));
            }
            out.push(PromLine::Type { name: name.to_string(), kind: kind.to_string() });
            continue;
        }
        if line.starts_with('#') {
            continue; // comment
        }
        out.push(parse_sample(line)?);
    }
    Ok(out)
}

fn parse_sample(line: &str) -> Result<PromLine, String> {
    let bytes = line.as_bytes();
    let name_end = bytes
        .iter()
        .position(|&b| b == b'{' || b == b' ')
        .ok_or_else(|| format!("sample line without value: {line}"))?;
    let name = &line[..name_end];
    if name.is_empty()
        || !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        || name.chars().next().is_some_and(|c| c.is_ascii_digit())
    {
        return Err(format!("invalid metric name in: {line}"));
    }
    let mut labels = Vec::new();
    let mut pos = name_end;
    if bytes[pos] == b'{' {
        pos += 1;
        loop {
            if pos >= bytes.len() {
                return Err(format!("unterminated label set: {line}"));
            }
            if bytes[pos] == b'}' {
                pos += 1;
                break;
            }
            let key_end = line[pos..]
                .find('=')
                .ok_or_else(|| format!("label without '=': {line}"))?
                + pos;
            let key = line[pos..key_end].to_string();
            if key.is_empty() {
                return Err(format!("empty label key: {line}"));
            }
            if bytes.get(key_end + 1) != Some(&b'"') {
                return Err(format!("label value not quoted: {line}"));
            }
            let mut value = String::new();
            let mut i = key_end + 2;
            loop {
                match bytes.get(i) {
                    None => return Err(format!("unterminated label value: {line}")),
                    Some(b'"') => break,
                    Some(b'\\') => {
                        match bytes.get(i + 1) {
                            Some(b'\\') => value.push('\\'),
                            Some(b'"') => value.push('"'),
                            Some(b'n') => value.push('\n'),
                            _ => return Err(format!("bad escape in label value: {line}")),
                        }
                        i += 2;
                    }
                    Some(_) => {
                        // Multi-byte UTF-8 is passed through unharmed:
                        // walk to the next char boundary.
                        let mut j = i + 1;
                        while j < bytes.len() && !line.is_char_boundary(j) {
                            j += 1;
                        }
                        value.push_str(&line[i..j]);
                        i = j;
                    }
                }
            }
            labels.push((key, value));
            pos = i + 1;
            if bytes.get(pos) == Some(&b',') {
                pos += 1;
            }
        }
    }
    let rest = line[pos..].trim_start();
    let value_str = rest.split_whitespace().next().unwrap_or("");
    let value = match value_str {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        s => s
            .parse::<f64>()
            .map_err(|_| format!("unparseable sample value: {line}"))?,
    };
    Ok(PromLine::Sample { name: name.to_string(), labels, value })
}

fn unescape(v: &str, label: bool) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = v.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('"') if label => out.push('"'),
                other => return Err(format!("bad escape \\{other:?} in: {v}")),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_families() -> Vec<PromFamily> {
        let mut h = Log2Histogram::new();
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(1000);
        }
        vec![
            PromFamily {
                name: "sinkhorn_queries_total",
                help: "Distance queries served",
                kind: PromKind::Counter,
                samples: vec![
                    PromSample {
                        labels: vec![("tenant", "m0".into())],
                        value: PromValue::Counter(42),
                    },
                    PromSample {
                        labels: vec![("tenant", "m1".into())],
                        value: PromValue::Counter(7),
                    },
                ],
            },
            PromFamily {
                name: "sinkhorn_retrieval_queue_depth",
                help: "Jobs queued or running",
                kind: PromKind::Gauge,
                samples: vec![PromSample { labels: vec![], value: PromValue::Gauge(3.5) }],
            },
            PromFamily {
                name: "sinkhorn_query_latency_us",
                help: "Query latency \\ \"quoted\"\nsecond line",
                kind: PromKind::Histogram,
                samples: vec![PromSample {
                    labels: vec![("tenant", "m\"0\\\n".into())],
                    value: PromValue::histogram(&h, 19_000),
                }],
            },
        ]
    }

    #[test]
    fn render_round_trips_through_the_parser() {
        let families = sample_families();
        let text = render_prometheus(&families);
        let lines = parse_exposition(&text).expect("parse back what we rendered");

        // Header pairs in order, one per family.
        let helps: Vec<&PromLine> =
            lines.iter().filter(|l| matches!(l, PromLine::Help { .. })).collect();
        assert_eq!(helps.len(), 3);
        match helps[2] {
            PromLine::Help { name, help } => {
                assert_eq!(name, "sinkhorn_query_latency_us");
                assert_eq!(help, "Query latency \\ \"quoted\"\nsecond line", "help escaping round-trips");
            }
            _ => unreachable!(),
        }
        match &lines[1] {
            PromLine::Type { name, kind } => {
                assert_eq!(name, "sinkhorn_queries_total");
                assert_eq!(kind, "counter");
            }
            other => panic!("expected TYPE after HELP, got {other:?}"),
        }

        // Counter samples keep per-tenant labels and values.
        let samples: Vec<&PromLine> =
            lines.iter().filter(|l| matches!(l, PromLine::Sample { .. })).collect();
        match samples[0] {
            PromLine::Sample { name, labels, value } => {
                assert_eq!(name, "sinkhorn_queries_total");
                assert_eq!(labels, &[("tenant".to_string(), "m0".to_string())]);
                assert_eq!(*value, 42.0);
            }
            _ => unreachable!(),
        }

        // Histogram series: ascending le, cumulative counts, +Inf=count.
        let buckets: Vec<(f64, f64)> = lines
            .iter()
            .filter_map(|l| match l {
                PromLine::Sample { name, labels, value }
                    if name == "sinkhorn_query_latency_us_bucket" =>
                {
                    let le = labels.iter().find(|(k, _)| k == "le").expect("le label");
                    let le = match le.1.as_str() {
                        "+Inf" => f64::INFINITY,
                        s => s.parse().unwrap(),
                    };
                    Some((le, *value))
                }
                _ => None,
            })
            .collect();
        assert!(!buckets.is_empty());
        for pair in buckets.windows(2) {
            assert!(pair[0].0 < pair[1].0, "le strictly ascending");
            assert!(pair[0].1 <= pair[1].1, "counts cumulative");
        }
        // 100 lands in bucket 6 (le=127), 1000 in bucket 9 (le=1023).
        assert!(buckets.contains(&(127.0, 90.0)));
        assert_eq!(buckets.last().unwrap(), &(f64::INFINITY, 100.0));
        let sum = lines.iter().find_map(|l| match l {
            PromLine::Sample { name, value, .. }
                if name == "sinkhorn_query_latency_us_sum" =>
            {
                Some(*value)
            }
            _ => None,
        });
        assert_eq!(sum, Some(19_000.0));
        let count = lines.iter().find_map(|l| match l {
            PromLine::Sample { name, value, .. }
                if name == "sinkhorn_query_latency_us_count" =>
            {
                Some(*value)
            }
            _ => None,
        });
        assert_eq!(count, Some(100.0));

        // The escaped label value survives the round trip.
        let escaped = lines.iter().find_map(|l| match l {
            PromLine::Sample { name, labels, .. }
                if name == "sinkhorn_query_latency_us_count" =>
            {
                labels.iter().find(|(k, _)| k == "tenant").map(|(_, v)| v.clone())
            }
            _ => None,
        });
        assert_eq!(escaped.as_deref(), Some("m\"0\\\n"));
    }

    #[test]
    fn golden_exposition_snapshot() {
        // A hand-checked golden rendering: header order, label quoting,
        // cumulative buckets, +Inf, _sum/_count. Guards accidental
        // format drift (Prometheus is strict about this grammar).
        let mut h = Log2Histogram::new();
        h.record(3);
        h.record(5);
        let families = vec![
            PromFamily {
                name: "sinkhorn_errors_total",
                help: "Failed queries",
                kind: PromKind::Counter,
                samples: vec![PromSample { labels: vec![], value: PromValue::Counter(0) }],
            },
            PromFamily {
                name: "sinkhorn_w_us",
                help: "w",
                kind: PromKind::Histogram,
                samples: vec![PromSample {
                    labels: vec![("tenant", "c2".into())],
                    value: PromValue::histogram(&h, 8),
                }],
            },
        ];
        let expected = "\
# HELP sinkhorn_errors_total Failed queries
# TYPE sinkhorn_errors_total counter
sinkhorn_errors_total 0
# HELP sinkhorn_w_us w
# TYPE sinkhorn_w_us histogram
sinkhorn_w_us_bucket{tenant=\"c2\",le=\"1\"} 0
sinkhorn_w_us_bucket{tenant=\"c2\",le=\"3\"} 1
sinkhorn_w_us_bucket{tenant=\"c2\",le=\"7\"} 2
sinkhorn_w_us_bucket{tenant=\"c2\",le=\"+Inf\"} 2
sinkhorn_w_us_sum{tenant=\"c2\"} 8
sinkhorn_w_us_count{tenant=\"c2\"} 2
";
        assert_eq!(render_prometheus(&families), expected);
        parse_exposition(expected).expect("golden text parses");
    }

    #[test]
    fn empty_histogram_renders_inf_bucket_only() {
        let h = Log2Histogram::new();
        let fam = PromFamily {
            name: "sinkhorn_empty_us",
            help: "e",
            kind: PromKind::Histogram,
            samples: vec![PromSample { labels: vec![], value: PromValue::histogram(&h, 0) }],
        };
        let text = render_prometheus(&[fam]);
        assert!(text.contains("sinkhorn_empty_us_bucket{le=\"+Inf\"} 0\n"));
        assert!(text.contains("sinkhorn_empty_us_count 0\n"));
        assert!(!text.contains("le=\"1\""));
    }

    #[test]
    fn clamp_bucket_folds_into_inf() {
        let mut h = Log2Histogram::new();
        h.record(u64::MAX); // bucket 31: unbounded above, must not claim a finite le
        let v = PromValue::histogram(&h, 1);
        match v {
            PromValue::Histogram { buckets, count, .. } => {
                assert_eq!(count, 1);
                // Finite buckets stop at bucket 30's bound; the clamp
                // bucket's mass appears only at +Inf (count).
                let max_le = buckets.last().map(|(le, _)| *le).unwrap_or(0);
                assert!(max_le <= (1u64 << 31) - 1);
                let max_cum = buckets.last().map(|(_, c)| *c).unwrap_or(0);
                assert_eq!(max_cum, 0, "clamped sample only counted at +Inf");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_exposition("sinkhorn_x{tenant=\"m0\" 3").is_err(), "unterminated labels");
        assert!(parse_exposition("sinkhorn_x{tenant=m0} 3").is_err(), "unquoted value");
        assert!(parse_exposition("sinkhorn_x abc").is_err(), "non-numeric value");
        assert!(parse_exposition("9sinkhorn_x 1").is_err(), "digit-leading name");
        assert!(parse_exposition("# TYPE sinkhorn_x flavor").is_err(), "unknown kind");
    }
}
