//! Telemetry (PR 10): metrics registry, sliding-window rollups,
//! Prometheus exposition and the per-tenant SLO monitor.
//!
//! Four pieces, layered:
//!
//! * [`registry`] — a zero-dependency instrument registry: typed
//!   `Counter` / `Gauge` / `Histogram` handles with stable names and
//!   label sets (`tenant`, `backend`, `stage`). The coordinator's
//!   [`crate::coordinator::metrics::Stats`] is built on it, so every
//!   serving gauge is a registered instrument instead of an ad-hoc
//!   struct field.
//! * **Windows** — with a [`TelemetryConfig`] set, each counter and
//!   histogram additionally folds into a ring of `windows` fixed-width
//!   slots (default 12 × 10 s), so p50/p99 latency, deadline-miss rate,
//!   warm-hit rate, recall and certified-interval-width quantiles are
//!   answerable "over the last minute", per tenant — not just since
//!   process start. Histogram slots fold via
//!   [`crate::util::histogram::Log2Histogram::merge`].
//! * [`exporter`] — `render_prometheus()` (text exposition v0.0.4) and
//!   the minimal scrape [`server`] bound from the engine: `/metrics`,
//!   `/healthz`, `/snapshot` (JSON) and `/slo` (windowed report).
//! * [`slo`] — declarative [`SloPolicy`] evaluated per window with
//!   fast/slow burn-rate gauges; a tenant whose latency SLO burns is
//!   **armed** and its batches are shed to the policy's iteration cap
//!   through the PR 6 `shed_cap` path.
//!
//! ## Zero-overhead contract
//!
//! Telemetry is **off by default** (`CoordinatorConfig::telemetry:
//! Option<TelemetryConfig>` = `None`). Off means: no scrape server
//! thread, no window rings, no per-tenant instruments, no clock reads on
//! the hot path — instrument updates degrade to the same plain integer
//! folds `Stats` always did, and all PR 1–9 bit-identity and latency
//! contracts are untouched.

pub mod exporter;
pub mod registry;
pub mod server;
pub mod slo;

pub use exporter::{
    parse_exposition, render_prometheus, PromFamily, PromKind, PromLine, PromSample,
    PromValue, PROMETHEUS_CONTENT_TYPE,
};
pub use registry::{CounterId, GaugeId, HistogramId, Labels, Registry};
pub use server::{http_get, ScrapeBody, ScrapeKind, TelemetryServer};
pub use slo::{CorpusSlo, SloMonitor, SloPolicy, TelemetryReport, TenantSlo};

use std::time::Duration;

/// Telemetry knobs, set via `CoordinatorConfigBuilder::telemetry(..)`.
/// Default **off** (the config field is an `Option`);
/// `TelemetryConfig::default()` binds an ephemeral localhost port with
/// a 12 × 10 s window ring and no SLO policy.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Scrape server bind address, e.g. `"127.0.0.1:9464"`; `":0"` ports
    /// resolve at bind time ([`crate::coordinator::DistanceService::
    /// scrape_addr`] reports the result).
    pub bind: String,
    /// Width of one rollup window.
    pub window: Duration,
    /// Number of windows in the ring (the "over the last minute" span is
    /// `window × windows`). Must be ≥ 2 — burn-rate alerting needs a
    /// current and a previous window.
    pub windows: usize,
    /// Optional per-tenant SLO policy (alerting + policy-driven
    /// shedding). `None` serves windowed rollups without alerting.
    pub slo: Option<SloPolicy>,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:0".into(),
            window: Duration::from_secs(10),
            windows: 12,
            slo: None,
        }
    }
}

impl TelemetryConfig {
    /// Validate the knobs; mirrors `CoordinatorConfig::validate` style.
    pub fn validate(&self) -> Result<(), String> {
        if self.bind.is_empty() {
            return Err("telemetry.bind must be a host:port address".into());
        }
        if self.window.is_zero() {
            return Err("telemetry.window must be nonzero".into());
        }
        if self.windows < 2 {
            return Err(
                "telemetry.windows must be >= 2 (burn rates need a current and \
                 a previous window)"
                    .into(),
            );
        }
        if let Some(slo) = &self.slo {
            slo.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn telemetry_config_validation() {
        TelemetryConfig::default().validate().unwrap();
        let base = TelemetryConfig::default();
        let err = TelemetryConfig { bind: String::new(), ..base.clone() }
            .validate()
            .unwrap_err();
        assert!(err.contains("bind"), "{err}");
        let err = TelemetryConfig { window: Duration::ZERO, ..base.clone() }
            .validate()
            .unwrap_err();
        assert!(err.contains("window"), "{err}");
        let err = TelemetryConfig { windows: 1, ..base.clone() }.validate().unwrap_err();
        assert!(err.contains("windows"), "{err}");
        let err = TelemetryConfig {
            slo: Some(SloPolicy { fast_burn: -1.0, ..SloPolicy::default() }),
            ..base
        }
        .validate()
        .unwrap_err();
        assert!(err.contains("fast_burn"), "{err}");
    }
}
