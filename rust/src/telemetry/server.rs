//! Minimal std-`TcpListener` scrape server.
//!
//! One background thread accepts connections (non-blocking listener +
//! short sleep poll so shutdown is prompt), parses just the request line
//! of an HTTP/1.x GET, and answers `/metrics`, `/healthz` and
//! `/snapshot` by round-tripping a scrape request through the engine
//! thread's message loop — the server never touches the registry
//! directly, so the registry stays single-threaded and lock-free.
//!
//! This is deliberately not a general HTTP server: no keep-alive, no
//! chunking, no TLS — exactly enough for a Prometheus scraper and a
//! curl-ing operator.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Which endpoint a scrape request wants. Routed by the engine thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScrapeKind {
    /// `/metrics` — Prometheus text exposition.
    Metrics,
    /// `/healthz` — JSON liveness: engine + retrieval runtime + queue depths.
    Healthz,
    /// `/snapshot` — the full `StatsSnapshot` as JSON.
    Snapshot,
    /// Programmatic windowed SLO report (also used by `serve_demo`).
    SloReport,
}

/// A rendered scrape response body.
#[derive(Debug, Clone)]
pub struct ScrapeBody {
    pub content_type: &'static str,
    pub body: String,
}

/// Handle to the running scrape server; dropping (or `stop`) joins the
/// accept thread.
pub struct TelemetryServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for TelemetryServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetryServer").field("addr", &self.addr).finish()
    }
}

impl TelemetryServer {
    /// Bind `bind` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve scrapes through `handler`. The handler runs on the server
    /// thread and is expected to round-trip the engine's message loop;
    /// `None` means the engine is gone and renders as 503.
    pub fn start(
        bind: &str,
        handler: impl Fn(ScrapeKind) -> Option<ScrapeBody> + Send + 'static,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let handle = std::thread::Builder::new()
            .name("sinkhorn-telemetry".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => serve_one(stream, &handler),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(20));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(20)),
                    }
                }
            })?;
        Ok(Self { addr, shutdown, handle: Some(handle) })
    }

    /// The bound address (port resolved when `bind` asked for `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal and join the accept thread.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for TelemetryServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(mut stream: TcpStream, handler: &impl Fn(ScrapeKind) -> Option<ScrapeBody>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_nonblocking(false);
    // Read until the end of headers (or a small cap — scrapes are tiny).
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let request = String::from_utf8_lossy(&buf);
    let mut parts = request.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let path = path.split('?').next().unwrap_or(path);
    if method != "GET" {
        respond(&mut stream, 405, "text/plain; charset=utf-8", "method not allowed\n");
        return;
    }
    let kind = match path {
        "/metrics" => ScrapeKind::Metrics,
        "/healthz" => ScrapeKind::Healthz,
        "/snapshot" => ScrapeKind::Snapshot,
        "/slo" => ScrapeKind::SloReport,
        _ => {
            respond(&mut stream, 404, "text/plain; charset=utf-8", "not found\n");
            return;
        }
    };
    match handler(kind) {
        Some(body) => respond(&mut stream, 200, body.content_type, &body.body),
        None => respond(
            &mut stream,
            503,
            "text/plain; charset=utf-8",
            "engine unavailable\n",
        ),
    }
}

fn respond(stream: &mut TcpStream, status: u16, content_type: &str, body: &str) {
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Service Unavailable",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// Tiny test/demo-side HTTP GET against the scrape server: returns
/// `(status, body)`. Lives here so the e2e tests, bench and `serve_demo`
/// don't each hand-roll a client.
pub fn http_get(addr: SocketAddr, path: &str, timeout: Duration) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let status = response
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "malformed status line")
        })?;
    let body = match response.split_once("\r\n\r\n") {
        Some((_, body)) => body.to_string(),
        None => String::new(),
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_routes_and_shuts_down() {
        let mut server = TelemetryServer::start("127.0.0.1:0", |kind| match kind {
            ScrapeKind::Metrics => Some(ScrapeBody {
                content_type: super::super::exporter::PROMETHEUS_CONTENT_TYPE,
                body: "sinkhorn_queries_total 1\n".into(),
            }),
            ScrapeKind::Healthz => Some(ScrapeBody {
                content_type: "application/json",
                body: "{\"status\":\"ok\"}".into(),
            }),
            _ => None,
        })
        .expect("bind ephemeral port");
        let addr = server.addr();

        let (status, body) =
            http_get(addr, "/metrics", Duration::from_secs(2)).expect("scrape");
        assert_eq!(status, 200);
        assert!(body.contains("sinkhorn_queries_total 1"));

        let (status, body) =
            http_get(addr, "/healthz", Duration::from_secs(2)).expect("healthz");
        assert_eq!(status, 200);
        assert!(body.contains("\"ok\""));

        let (status, _) =
            http_get(addr, "/snapshot", Duration::from_secs(2)).expect("snapshot");
        assert_eq!(status, 503, "handler returning None renders 503");

        let (status, _) =
            http_get(addr, "/nope", Duration::from_secs(2)).expect("404 path");
        assert_eq!(status, 404);

        server.stop();
        // After stop the port no longer accepts (listener dropped).
        assert!(TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_err());
    }
}
