//! Zero-dependency metrics registry with optional sliding-window rollups.
//!
//! Instruments are registered once — `counter` / `gauge` / `histogram`
//! return small `Copy` index handles into dense cell vectors — and every
//! subsequent operation is an O(1) vector index plus a plain integer
//! fold: no atomics, no maps, no locks. The registry is single-threaded
//! by design; it lives inside the engine thread's
//! [`crate::coordinator::metrics::Stats`] and is only ever read through
//! the engine's message loop (the scrape server round-trips a
//! `Message::Scrape` instead of sharing memory).
//!
//! ## Windows
//!
//! Constructed with a window spec, each counter and histogram cell
//! additionally owns a [`Ring`] of `windows` fixed-width slots keyed by
//! the *window ordinal* `now_us / width_us + 1` (ordinal 0 is the empty
//! sentinel). Recording lazily resets a slot whose ordinal went stale, so
//! there is no background ticker; reads fold the slots whose ordinals lie
//! in `(current - windows, current]`. With no window spec (telemetry
//! off) the rings are `None` and recording never reads the clock.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::trace::Tenant;
use crate::util::histogram::Log2Histogram;
use crate::util::saturating_micros;

/// The label set an instrument is registered under. All three keys are
/// optional; instruments sharing a name but differing labels form one
/// Prometheus family. Ordered so rendering is deterministic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Labels {
    /// Which tenant the series describes (`m<id>` metric / `c<id>` corpus).
    pub tenant: Option<Tenant>,
    /// Which backend served (`"xla"` / `"cpu"`).
    pub backend: Option<&'static str>,
    /// Which pipeline stage a span histogram covers.
    pub stage: Option<&'static str>,
}

impl Labels {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn tenant(tenant: Tenant) -> Self {
        Self { tenant: Some(tenant), ..Self::default() }
    }

    pub fn backend(backend: &'static str) -> Self {
        Self { backend: Some(backend), ..Self::default() }
    }

    pub fn stage_tenant(stage: &'static str, tenant: Tenant) -> Self {
        Self { tenant: Some(tenant), stage: Some(stage), backend: None }
    }

    /// Rendered `key=value` pairs in fixed (alphabetical) key order.
    pub fn pairs(&self) -> Vec<(&'static str, String)> {
        let mut out = Vec::new();
        if let Some(b) = self.backend {
            out.push(("backend", b.to_string()));
        }
        if let Some(s) = self.stage {
            out.push(("stage", s.to_string()));
        }
        if let Some(t) = self.tenant {
            out.push(("tenant", t.label()));
        }
        out
    }
}

/// Handle to a registered counter. Plain index — `Copy`, cheap to store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(usize);

/// Handle to a registered gauge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(usize);

/// Handle to a registered histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistogramId(usize);

/// Ring of `n` fixed-width windows. Slot `ord % n` holds the fold for
/// window ordinal `ord`; a slot is lazily reset when a newer ordinal
/// lands on it, and excluded on read when its ordinal fell out of the
/// live range.
#[derive(Debug, Clone)]
pub struct Ring<T> {
    width_us: u64,
    slots: Vec<(u64, T)>,
}

impl<T: Default + Clone> Ring<T> {
    fn new(width: Duration, windows: usize) -> Self {
        Self {
            width_us: saturating_micros(width).max(1),
            slots: vec![(0, T::default()); windows.max(2)],
        }
    }

    /// Ordinal of the window containing `now_us` (always ≥ 1, so 0 can
    /// mark an empty slot).
    fn ordinal(&self, now_us: u64) -> u64 {
        now_us / self.width_us + 1
    }

    /// The slot for `now_us`, reset if it last held an older window.
    fn slot_mut(&mut self, now_us: u64) -> &mut T {
        let ord = self.ordinal(now_us);
        let n = self.slots.len() as u64;
        let slot = &mut self.slots[(ord % n) as usize];
        if slot.0 != ord {
            *slot = (ord, T::default());
        }
        &mut slot.1
    }

    /// Fold over the slots whose ordinal lies in `(cur - back, cur]` —
    /// `back = slots.len()` reads the whole live ring.
    fn fold_recent<A>(&self, now_us: u64, back: usize, mut acc: A, f: impl Fn(&mut A, &T)) -> A {
        let cur = self.ordinal(now_us);
        let back = (back.min(self.slots.len())) as u64;
        for (ord, value) in &self.slots {
            if *ord != 0 && *ord <= cur && *ord + back > cur {
                f(&mut acc, value);
            }
        }
        acc
    }
}

/// Windowed histogram slot: the distribution plus its exact sum (the
/// log2 buckets alone cannot answer `_sum`).
#[derive(Debug, Clone, Default)]
struct HistoSlot {
    h: Log2Histogram,
    sum: u128,
}

#[derive(Debug, Clone)]
struct Meta {
    name: &'static str,
    help: &'static str,
    labels: Labels,
}

#[derive(Debug, Clone)]
struct CounterCell {
    meta: Meta,
    total: u64,
    ring: Option<Ring<u64>>,
}

#[derive(Debug, Clone)]
struct GaugeCell {
    meta: Meta,
    value: f64,
}

#[derive(Debug, Clone)]
struct HistoCell {
    meta: Meta,
    cum: Log2Histogram,
    sum: u128,
    ring: Option<Ring<HistoSlot>>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

/// The registry. See the module docs for the design contract.
#[derive(Debug, Clone)]
pub struct Registry {
    epoch: Instant,
    window: Option<(Duration, usize)>,
    counters: Vec<CounterCell>,
    gauges: Vec<GaugeCell>,
    histos: Vec<HistoCell>,
    index: BTreeMap<(&'static str, Labels), (Kind, usize)>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new(None)
    }
}

impl Registry {
    /// `window = Some((width, n))` arms sliding-window rollups on every
    /// counter and histogram; `None` (telemetry off) keeps cells
    /// ring-free and recording clock-free.
    pub fn new(window: Option<(Duration, usize)>) -> Self {
        Self {
            epoch: Instant::now(),
            window,
            counters: Vec::new(),
            gauges: Vec::new(),
            histos: Vec::new(),
            index: BTreeMap::new(),
        }
    }

    /// Whether windowed rollups are armed.
    pub fn windowed(&self) -> bool {
        self.window.is_some()
    }

    /// Number of window slots (0 when windows are off).
    pub fn window_count(&self) -> usize {
        self.window.map(|(_, n)| n.max(2)).unwrap_or(0)
    }

    /// Microseconds since the registry's epoch (only read on windowed
    /// operations).
    fn now_us(&self) -> u64 {
        saturating_micros(self.epoch.elapsed())
    }

    /// Current window ordinal, 0 when windows are off.
    pub fn window_ordinal(&self) -> u64 {
        match self.window {
            Some((width, _)) => {
                self.now_us() / saturating_micros(width).max(1) + 1
            }
            None => 0,
        }
    }

    /// Register (or look up) a counter. Idempotent per (name, labels).
    pub fn counter(&mut self, name: &'static str, help: &'static str, labels: Labels) -> CounterId {
        if let Some(&(kind, i)) = self.index.get(&(name, labels)) {
            debug_assert_eq!(kind, Kind::Counter, "{name} re-registered as a different kind");
            return CounterId(i);
        }
        let i = self.counters.len();
        self.counters.push(CounterCell {
            meta: Meta { name, help, labels },
            total: 0,
            ring: self.window.map(|(w, n)| Ring::new(w, n)),
        });
        self.index.insert((name, labels), (Kind::Counter, i));
        CounterId(i)
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&mut self, name: &'static str, help: &'static str, labels: Labels) -> GaugeId {
        if let Some(&(kind, i)) = self.index.get(&(name, labels)) {
            debug_assert_eq!(kind, Kind::Gauge, "{name} re-registered as a different kind");
            return GaugeId(i);
        }
        let i = self.gauges.len();
        self.gauges.push(GaugeCell { meta: Meta { name, help, labels }, value: 0.0 });
        self.index.insert((name, labels), (Kind::Gauge, i));
        GaugeId(i)
    }

    /// Register (or look up) a histogram.
    pub fn histogram(
        &mut self,
        name: &'static str,
        help: &'static str,
        labels: Labels,
    ) -> HistogramId {
        if let Some(&(kind, i)) = self.index.get(&(name, labels)) {
            debug_assert_eq!(kind, Kind::Histogram, "{name} re-registered as a different kind");
            return HistogramId(i);
        }
        let i = self.histos.len();
        self.histos.push(HistoCell {
            meta: Meta { name, help, labels },
            cum: Log2Histogram::new(),
            sum: 0,
            ring: self.window.map(|(w, n)| Ring::new(w, n)),
        });
        self.index.insert((name, labels), (Kind::Histogram, i));
        HistogramId(i)
    }

    /// Increment a counter.
    #[inline]
    pub fn add(&mut self, id: CounterId, n: u64) {
        let now = self.counters[id.0].ring.as_ref().map(|_| self.now_us());
        let cell = &mut self.counters[id.0];
        cell.total = cell.total.saturating_add(n);
        if let (Some(ring), Some(now)) = (cell.ring.as_mut(), now) {
            let slot = ring.slot_mut(now);
            *slot = slot.saturating_add(n);
        }
    }

    /// Set a gauge.
    #[inline]
    pub fn set(&mut self, id: GaugeId, v: f64) {
        self.gauges[id.0].value = v;
    }

    /// Record a histogram sample.
    #[inline]
    pub fn observe(&mut self, id: HistogramId, v: u64) {
        let now = self.histos[id.0].ring.as_ref().map(|_| self.now_us());
        let cell = &mut self.histos[id.0];
        cell.cum.record(v);
        cell.sum += v as u128;
        if let (Some(ring), Some(now)) = (cell.ring.as_mut(), now) {
            let slot = ring.slot_mut(now);
            slot.h.record(v);
            slot.sum += v as u128;
        }
    }

    /// Cumulative counter value.
    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0].total
    }

    /// Counter folded over the last `back` windows (`usize::MAX` = the
    /// whole live ring). 0 when windows are off.
    pub fn counter_recent(&self, id: CounterId, back: usize) -> u64 {
        match &self.counters[id.0].ring {
            Some(ring) => {
                ring.fold_recent(self.now_us(), back, 0u64, |acc, v| *acc = acc.saturating_add(*v))
            }
            None => 0,
        }
    }

    /// Counter folded over the whole live ring.
    pub fn counter_windowed(&self, id: CounterId) -> u64 {
        self.counter_recent(id, usize::MAX)
    }

    /// Gauge value.
    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        self.gauges[id.0].value
    }

    /// Cumulative histogram (and its exact sample sum).
    pub fn histogram_cum(&self, id: HistogramId) -> (&Log2Histogram, u128) {
        let cell = &self.histos[id.0];
        (&cell.cum, cell.sum)
    }

    /// Histogram merged over the last `back` windows. Empty when windows
    /// are off.
    pub fn histogram_recent(&self, id: HistogramId, back: usize) -> Log2Histogram {
        match &self.histos[id.0].ring {
            Some(ring) => ring.fold_recent(
                self.now_us(),
                back,
                Log2Histogram::new(),
                |acc, slot| acc.merge(&slot.h),
            ),
            None => Log2Histogram::new(),
        }
    }

    /// Histogram merged over the whole live ring.
    pub fn histogram_windowed(&self, id: HistogramId) -> Log2Histogram {
        self.histogram_recent(id, usize::MAX)
    }

    /// Every registered instrument as Prometheus families, grouped by
    /// name in ascending (name, labels) order.
    pub fn families(&self) -> Vec<super::exporter::PromFamily> {
        use super::exporter::{PromFamily, PromKind, PromSample, PromValue};
        let mut out: Vec<PromFamily> = Vec::new();
        for ((name, _), (kind, i)) in &self.index {
            let (meta, value) = match kind {
                Kind::Counter => {
                    let c = &self.counters[*i];
                    (&c.meta, PromValue::Counter(c.total))
                }
                Kind::Gauge => {
                    let g = &self.gauges[*i];
                    (&g.meta, PromValue::Gauge(g.value))
                }
                Kind::Histogram => {
                    let h = &self.histos[*i];
                    (&h.meta, PromValue::histogram(&h.cum, h.sum))
                }
            };
            let prom_kind = match kind {
                Kind::Counter => PromKind::Counter,
                Kind::Gauge => PromKind::Gauge,
                Kind::Histogram => PromKind::Histogram,
            };
            let sample = PromSample { labels: meta.labels.pairs(), value };
            match out.last_mut() {
                Some(fam) if fam.name == *name => fam.samples.push(sample),
                _ => out.push(PromFamily {
                    name,
                    help: meta.help,
                    kind: prom_kind,
                    samples: vec![sample],
                }),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwindowed_registry_counts_without_clock_state() {
        let mut reg = Registry::new(None);
        let c = reg.counter("sinkhorn_test_total", "test", Labels::none());
        reg.add(c, 3);
        reg.add(c, 2);
        assert_eq!(reg.counter_value(c), 5);
        assert_eq!(reg.counter_windowed(c), 0, "no ring when windows off");
        assert!(!reg.windowed());
        assert_eq!(reg.window_ordinal(), 0);
    }

    #[test]
    fn registration_is_idempotent_per_name_and_labels() {
        let mut reg = Registry::new(None);
        let a = reg.counter("sinkhorn_x_total", "x", Labels::none());
        let b = reg.counter("sinkhorn_x_total", "x", Labels::none());
        assert_eq!(a, b);
        let c = reg.counter("sinkhorn_x_total", "x", Labels::tenant(Tenant::Metric(1)));
        assert_ne!(a, c, "distinct labels → distinct instrument");
        reg.add(a, 1);
        reg.add(c, 7);
        assert_eq!(reg.counter_value(b), 1);
        assert_eq!(reg.counter_value(c), 7);
    }

    #[test]
    fn windowed_counter_decays_after_the_ring_slides() {
        let mut reg = Registry::new(Some((Duration::from_millis(20), 3)));
        let c = reg.counter("sinkhorn_miss_total", "miss", Labels::none());
        reg.add(c, 4);
        assert_eq!(reg.counter_value(c), 4);
        assert_eq!(reg.counter_windowed(c), 4);
        // Sleep past the whole ring: the cumulative value must hold while
        // the windowed view decays to zero.
        std::thread::sleep(Duration::from_millis(90));
        assert_eq!(reg.counter_value(c), 4);
        assert_eq!(reg.counter_windowed(c), 0);
    }

    #[test]
    fn windowed_histogram_merges_live_slots() {
        let mut reg = Registry::new(Some((Duration::from_secs(60), 4)));
        let h = reg.histogram("sinkhorn_lat_us", "lat", Labels::none());
        reg.observe(h, 100);
        reg.observe(h, 1000);
        let (cum, sum) = reg.histogram_cum(h);
        assert_eq!(cum.count(), 2);
        assert_eq!(sum, 1100);
        let win = reg.histogram_windowed(h);
        assert_eq!(win.count(), 2, "wide windows: both samples live");
        assert_eq!(win.observed_max(), 1000);
    }

    #[test]
    fn recent_counter_reads_a_sub_ring() {
        let mut reg = Registry::new(Some((Duration::from_secs(60), 12)));
        let c = reg.counter("sinkhorn_q_total", "q", Labels::none());
        reg.add(c, 9);
        // back=2 (current + previous window) sees the current slot.
        assert_eq!(reg.counter_recent(c, 2), 9);
        assert_eq!(reg.counter_recent(c, 1), 9);
    }

    #[test]
    fn ring_reset_reclaims_stale_slots() {
        let mut ring: Ring<u64> = Ring::new(Duration::from_micros(10), 2);
        *ring.slot_mut(0) += 5; // ordinal 1
        *ring.slot_mut(25) += 7; // ordinal 3 → same slot index as 1, reset
        let total = ring.fold_recent(25, usize::MAX, 0u64, |a, v| *a += v);
        assert_eq!(total, 7, "ordinal-1 slot was reclaimed by ordinal 3");
    }
}
