//! Closed-form 1-D optimal transportation.
//!
//! For histograms on the line with ground metric m_ij = |x_i − x_j| the
//! optimal transportation distance has the classical CDF form
//! d(r,c) = Σ_k |R_k − C_k| · (x_{k+1} − x_k) (Levina & Bickel, 2001 link
//! the EMD to the Mallows distance). With unit-spaced bins this is just
//! the ℓ₁ norm of the CDF difference. It serves three roles:
//!
//! * an *independent oracle* for the network simplex in tests;
//! * a fast O(d) path for genuine line metrics;
//! * an **admissible lower bound** on any transportation distance, via
//!   anchor projection ([`projection_lower_bound`]): project every bin
//!   onto the line through x_i = m_{a,i}; the reverse triangle
//!   inequality gives |x_i − x_j| ≤ m_ij, so the closed-form 1-D cost of
//!   the projected histograms can never exceed d_M — and since the
//!   served d_M^λ is the cost of a feasible plan, d_M ≤ d_M^λ extends
//!   the bound to the whole Sinkhorn family. The retrieval cascade
//!   ([`crate::retrieval`]) prunes corpus candidates on exactly this
//!   contract.
//!
//! [`quantile_transport`] is the general form: exact 1-D transport
//! between two weighted point sets with *different* supports and support
//! sizes (the merged-CDF integral ∫|F_r − F_c| dx).

use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Exact EMD between histograms on unit-spaced line bins (m_ij = |i−j|).
pub fn emd_1d(r: &[F], c: &[F]) -> F {
    assert_eq!(r.len(), c.len(), "histograms must share a dimension");
    let mut cum = 0.0;
    let mut total = 0.0;
    for k in 0..r.len().saturating_sub(1) {
        cum += r[k] - c[k];
        total += cum.abs();
    }
    total
}

/// Exact EMD on arbitrary sorted bin positions: ground metric
/// m_ij = |x_i − x_j|.
pub fn emd_1d_positions(r: &[F], c: &[F], x: &[F]) -> F {
    assert_eq!(r.len(), c.len());
    assert_eq!(r.len(), x.len());
    debug_assert!(x.windows(2).all(|w| w[0] <= w[1]), "positions must be sorted");
    let mut cum = 0.0;
    let mut total = 0.0;
    for k in 0..r.len().saturating_sub(1) {
        cum += r[k] - c[k];
        total += cum.abs() * (x[k + 1] - x[k]);
    }
    total
}

/// Exact 1-D optimal transport between two weighted point sets on the
/// line — the quantile-transport (Mallows) form ∫|F_r − F_c| dx over the
/// merged support.
///
/// Unlike [`emd_1d_positions`] the two sides may have **different
/// supports and different support sizes**: `(r, xr)` and `(c, xc)` are
/// weight/position pairs, each with positions sorted ascending (asserted
/// in debug builds). Weights must be non-negative with equal total mass
/// (both sides normalized histograms in the intended use); the result is
/// the exact 1-D transportation cost under m(x, y) = |x − y|.
///
/// Degenerate cases: two point masses cost |xr − xc|; identical weighted
/// supports cost 0; an empty side is a programming error (asserted).
pub fn quantile_transport(r: &[F], xr: &[F], c: &[F], xc: &[F]) -> F {
    assert_eq!(r.len(), xr.len(), "source weights/positions length mismatch");
    assert_eq!(c.len(), xc.len(), "target weights/positions length mismatch");
    assert!(!r.is_empty() && !c.is_empty(), "point sets must be non-empty");
    debug_assert!(xr.windows(2).all(|w| w[0] <= w[1]), "source positions sorted");
    debug_assert!(xc.windows(2).all(|w| w[0] <= w[1]), "target positions sorted");
    debug_assert!(
        (r.iter().sum::<F>() - c.iter().sum::<F>()).abs() < 1e-9,
        "transport needs equal total mass"
    );
    // Merge-walk the two sorted supports, integrating |F_r − F_c| over
    // each gap between consecutive breakpoints.
    let (mut i, mut j) = (0usize, 0usize);
    let (mut fr, mut fc) = (0.0, 0.0);
    let mut prev: Option<F> = None;
    let mut total = 0.0;
    while i < xr.len() || j < xc.len() {
        let x = match (xr.get(i), xc.get(j)) {
            (Some(&a), Some(&b)) => a.min(b),
            (Some(&a), None) => a,
            (None, Some(&b)) => b,
            (None, None) => unreachable!(),
        };
        if let Some(p) = prev {
            total += (fr - fc).abs() * (x - p);
        }
        while i < xr.len() && xr[i] <= x {
            fr += r[i];
            i += 1;
        }
        while j < xc.len() && xc[j] <= x {
            fc += c[j];
            j += 1;
        }
        prev = Some(x);
    }
    total
}

/// Admissible lower bound on d_M(r, c) — and therefore on the served
/// d_M^λ(r, c) for every λ, since d_M ≤ d_M^λ — from a 1-D anchor
/// projection, in O(d log d) (O(d) when the caller pre-sorts, as the
/// retrieval index does).
///
/// Project bin i to x_i = m_{anchor,i}. By the reverse triangle
/// inequality |x_i − x_j| = |m_{a,i} − m_{a,j}| ≤ m_ij, so every
/// feasible plan P satisfies ⟨P, M⟩ ≥ Σ P_ij|x_i − x_j| ≥ the 1-D
/// optimum computed here. Different anchors give different (incomparable)
/// bounds; taking the max over a small anchor set tightens it.
pub fn projection_lower_bound(
    m: &CostMatrix,
    anchor: usize,
    r: &Histogram,
    c: &Histogram,
) -> F {
    let d = m.dim();
    assert!(anchor < d, "anchor out of range");
    assert_eq!(r.dim(), d, "source dimension mismatch");
    assert_eq!(c.dim(), d, "target dimension mismatch");
    let mut perm: Vec<usize> = (0..d).collect();
    let row = m.row(anchor);
    perm.sort_by(|&a, &b| row[a].total_cmp(&row[b]).then(a.cmp(&b)));
    let x: Vec<F> = perm.iter().map(|&i| row[i]).collect();
    let rs: Vec<F> = perm.iter().map(|&i| r.values()[i]).collect();
    let cs: Vec<F> = perm.iter().map(|&i| c.values()[i]).collect();
    emd_1d_positions(&rs, &cs, &x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::{seeded_rng, Histogram};

    #[test]
    fn point_masses() {
        // delta_0 -> delta_3 over 4 bins costs 3.
        let r = [1.0, 0.0, 0.0, 0.0];
        let c = [0.0, 0.0, 0.0, 1.0];
        assert_eq!(emd_1d(&r, &c), 3.0);
    }

    #[test]
    fn positions_generalize_unit_spacing() {
        let mut rng = seeded_rng(2);
        let r = Histogram::sample_uniform(10, &mut rng);
        let c = Histogram::sample_uniform(10, &mut rng);
        let x: Vec<F> = (0..10).map(|i| i as F).collect();
        let a = emd_1d(r.values(), c.values());
        let b = emd_1d_positions(r.values(), c.values(), &x);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn scaling_positions_scales_distance() {
        let mut rng = seeded_rng(3);
        let r = Histogram::sample_uniform(8, &mut rng);
        let c = Histogram::sample_uniform(8, &mut rng);
        let x1: Vec<F> = (0..8).map(|i| i as F).collect();
        let x2: Vec<F> = (0..8).map(|i| 2.5 * i as F).collect();
        let a = emd_1d_positions(r.values(), c.values(), &x1);
        let b = emd_1d_positions(r.values(), c.values(), &x2);
        assert!((2.5 * a - b).abs() < 1e-12);
    }

    #[test]
    fn prop_symmetric_nonnegative_coincident() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(1, 64);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let ab = emd_1d(r.values(), c.values());
            let ba = emd_1d(c.values(), r.values());
            assert!(ab >= 0.0);
            assert!((ab - ba).abs() < 1e-12);
            assert!(emd_1d(r.values(), r.values()).abs() < 1e-15);
        }
    }

    #[test]
    fn quantile_transport_point_masses_and_degenerates() {
        // Two point masses cost their separation, regardless of support
        // sizes being 1 vs 1.
        assert!((quantile_transport(&[1.0], &[0.0], &[1.0], &[3.5]) - 3.5).abs() < 1e-12);
        // Identical weighted supports cost zero.
        let w = [0.25, 0.75];
        let x = [1.0, 4.0];
        assert_eq!(quantile_transport(&w, &x, &w, &x), 0.0);
        // Coincident positions with different weight splits still cost 0
        // only when the CDFs agree everywhere; here they differ on [1,4).
        let v = [0.75, 0.25];
        assert!((quantile_transport(&w, &x, &v, &x) - 0.5 * 3.0).abs() < 1e-12);
        // A point mass against a two-point split: 0.5·|2−0| + 0.5·|2−4|.
        assert!(
            (quantile_transport(&[1.0], &[2.0], &[0.5, 0.5], &[0.0, 4.0]) - 2.0).abs()
                < 1e-12
        );
    }

    #[test]
    fn quantile_transport_unequal_support_sizes_match_padded_form() {
        // (r over 3 points) vs (c over 5 points): pad both onto the
        // merged support and check against emd_1d_positions.
        let xr = [0.0, 1.0, 2.5];
        let r = [0.2, 0.5, 0.3];
        let xc = [0.5, 1.0, 1.5, 2.0, 3.0];
        let c = [0.1, 0.2, 0.3, 0.2, 0.2];
        let got = quantile_transport(&r, &xr, &c, &xc);
        // Merged support, histograms padded with zeros.
        let merged = [0.0, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0];
        let rp = [0.2, 0.0, 0.5, 0.0, 0.0, 0.3, 0.0];
        let cp = [0.0, 0.1, 0.2, 0.3, 0.2, 0.0, 0.2];
        let want = emd_1d_positions(&rp, &cp, &merged);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn quantile_transport_generalizes_shared_support_form() {
        for seed in 0..50u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 32);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let mut x: Vec<F> = (0..d).map(|_| rng.range_f64(0.0, 10.0)).collect();
            x.sort_by(F::total_cmp);
            let a = emd_1d_positions(r.values(), c.values(), &x);
            let b = quantile_transport(r.values(), &x, c.values(), &x);
            assert!((a - b).abs() < 1e-12, "seed={seed}: {a} vs {b}");
        }
    }

    #[test]
    fn projection_bound_is_admissible_and_exact_on_line_metrics() {
        use crate::metric::RandomMetric;
        use crate::ot::EmdSolver;
        for seed in 0..30u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(3, 20);
            let m = RandomMetric::new(d).sample(&mut rng);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let exact = EmdSolver::new(&m).solve(&r, &c).unwrap().cost;
            for anchor in [0, d / 2, d - 1] {
                let bound = projection_lower_bound(&m, anchor, &r, &c);
                assert!(
                    bound <= exact + 1e-9,
                    "seed={seed} anchor={anchor}: {bound} > d_M {exact}"
                );
                assert!(bound >= 0.0);
            }
            // Point-mass degenerate: bound equals the exact cost m_ij
            // when the anchor is one of the two occupied bins.
            let i = rng.range_usize(0, d);
            let mut j = rng.range_usize(0, d);
            if j == i {
                j = (j + 1) % d;
            }
            let di = Histogram::dirac(d, i);
            let dj = Histogram::dirac(d, j);
            let b = projection_lower_bound(&m, i, &di, &dj);
            assert!((b - m.get(i, j)).abs() < 1e-12);
        }
        // A genuine line metric: the anchor-0 projection recovers the
        // full 1-D optimum (positions m_{0,i} = |x_0 − x_i| reproduce the
        // line up to reflection, which 1-D transport cannot see).
        let d = 12;
        let mut rng = seeded_rng(99);
        let x: Vec<F> = (0..d).map(|i| i as F).collect();
        let mut data = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                data[i * d + j] = (x[i] - x[j]).abs();
            }
        }
        let m = CostMatrix::from_rows(d, data);
        let r = Histogram::sample_uniform(d, &mut rng);
        let c = Histogram::sample_uniform(d, &mut rng);
        let want = emd_1d(r.values(), c.values());
        let got = projection_lower_bound(&m, 0, &r, &c);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    /// TV lower bound: EMD >= TV on unit-spaced bins (moving mass at
    /// least one step costs at least its TV discrepancy).
    #[test]
    fn prop_dominates_total_variation() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 64);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let tv: F = 0.5 * r.values().iter().zip(c.values())
                .map(|(a, b)| (a - b).abs()).sum::<F>();
            assert!(emd_1d(r.values(), c.values()) >= tv - 1e-12);
        }
    }
}
