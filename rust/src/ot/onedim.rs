//! Closed-form 1-D optimal transportation.
//!
//! For histograms on the line with ground metric m_ij = |x_i − x_j| the
//! optimal transportation distance has the classical CDF form
//! d(r,c) = Σ_k |R_k − C_k| · (x_{k+1} − x_k) (Levina & Bickel, 2001 link
//! the EMD to the Mallows distance). With unit-spaced bins this is just
//! the ℓ₁ norm of the CDF difference. It serves as an *independent oracle*
//! for the network simplex in tests, and as a fast O(d) path for line
//! metrics.

use crate::F;

/// Exact EMD between histograms on unit-spaced line bins (m_ij = |i−j|).
pub fn emd_1d(r: &[F], c: &[F]) -> F {
    assert_eq!(r.len(), c.len(), "histograms must share a dimension");
    let mut cum = 0.0;
    let mut total = 0.0;
    for k in 0..r.len().saturating_sub(1) {
        cum += r[k] - c[k];
        total += cum.abs();
    }
    total
}

/// Exact EMD on arbitrary sorted bin positions: ground metric
/// m_ij = |x_i − x_j|.
pub fn emd_1d_positions(r: &[F], c: &[F], x: &[F]) -> F {
    assert_eq!(r.len(), c.len());
    assert_eq!(r.len(), x.len());
    debug_assert!(x.windows(2).all(|w| w[0] <= w[1]), "positions must be sorted");
    let mut cum = 0.0;
    let mut total = 0.0;
    for k in 0..r.len().saturating_sub(1) {
        cum += r[k] - c[k];
        total += cum.abs() * (x[k + 1] - x[k]);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::{seeded_rng, Histogram};

    #[test]
    fn point_masses() {
        // delta_0 -> delta_3 over 4 bins costs 3.
        let r = [1.0, 0.0, 0.0, 0.0];
        let c = [0.0, 0.0, 0.0, 1.0];
        assert_eq!(emd_1d(&r, &c), 3.0);
    }

    #[test]
    fn positions_generalize_unit_spacing() {
        let mut rng = seeded_rng(2);
        let r = Histogram::sample_uniform(10, &mut rng);
        let c = Histogram::sample_uniform(10, &mut rng);
        let x: Vec<F> = (0..10).map(|i| i as F).collect();
        let a = emd_1d(r.values(), c.values());
        let b = emd_1d_positions(r.values(), c.values(), &x);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn scaling_positions_scales_distance() {
        let mut rng = seeded_rng(3);
        let r = Histogram::sample_uniform(8, &mut rng);
        let c = Histogram::sample_uniform(8, &mut rng);
        let x1: Vec<F> = (0..8).map(|i| i as F).collect();
        let x2: Vec<F> = (0..8).map(|i| 2.5 * i as F).collect();
        let a = emd_1d_positions(r.values(), c.values(), &x1);
        let b = emd_1d_positions(r.values(), c.values(), &x2);
        assert!((2.5 * a - b).abs() < 1e-12);
    }

    #[test]
    fn prop_symmetric_nonnegative_coincident() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(1, 64);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let ab = emd_1d(r.values(), c.values());
            let ba = emd_1d(c.values(), r.values());
            assert!(ab >= 0.0);
            assert!((ab - ba).abs() < 1e-12);
            assert!(emd_1d(r.values(), r.values()).abs() < 1e-15);
        }
    }

    /// TV lower bound: EMD >= TV on unit-spaced bins (moving mass at
    /// least one step costs at least its TV discrepancy).
    #[test]
    fn prop_dominates_total_variation() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 64);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let tv: F = 0.5 * r.values().iter().zip(c.values())
                .map(|(a, b)| (a - b).abs()).sum::<F>();
            assert!(emd_1d(r.values(), c.values()) >= tv - 1e-12);
        }
    }
}
