//! Transportation network simplex — the exact d_M(r,c) solver.
//!
//! A primal network simplex specialized to the (complete bipartite)
//! transportation polytope U(r,c), the algorithm family behind every EMD
//! code the paper benchmarks (Rubner's transportation simplex, LEMON's
//! network simplex inside FastEMD-style solvers). Worst-case super-cubic
//! (§2.2, Pele & Werman §2.1) — which is exactly the behaviour Figure 4
//! documents against Sinkhorn.
//!
//! ## Algorithm
//!
//! * **Initial basis** — north-west-corner rule on *perturbed* marginals
//!   (r_i += δ, c_last += mδ): the classical anti-degeneracy device; every
//!   basic flow is strictly positive so no zero-pivot cycling can occur.
//! * **Pricing** — block search (Dantzig rule within blocks of ~d arcs,
//!   wrapping cursor), the standard compromise between steepest-descent
//!   pivot quality and O(d²) full scans.
//! * **Basis update** — the spanning tree over the m+n nodes is kept as an
//!   adjacency list of basic arcs; after each pivot the affected subtree's
//!   parents/depths/potentials are recomputed by BFS (O(d) per pivot).
//! * **Exact re-solve** — after optimality on the perturbed problem, the
//!   final basis (a spanning tree) is re-solved against the *unperturbed*
//!   marginals by leaf elimination, so returned flows and cost are exact
//!   for the original problem, and the potentials certify optimality.

use super::{OtError, TransportPlan};
use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Anti-degeneracy perturbation added to every supply.
const DELTA: F = 1e-11;
/// Dual feasibility tolerance for the pricing step.
const PRICE_EPS: F = 1e-12;

/// Counters reported with each solve.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimplexStats {
    /// Simplex pivots performed.
    pub pivots: usize,
    /// Entering-arc candidate scans (arcs priced).
    pub arcs_priced: usize,
    /// Positive (source) bins after support restriction.
    pub sources: usize,
    /// Positive (sink) bins after support restriction.
    pub sinks: usize,
}

/// One basic arc of the current spanning tree.
#[derive(Debug, Clone, Copy)]
struct BasicArc {
    /// Source index (0..m, support-local).
    src: u32,
    /// Sink index (0..n, support-local).
    snk: u32,
    flow: F,
    alive: bool,
}

pub struct NetworkSimplex<'m> {
    metric: &'m CostMatrix,
    pivot_limit: usize,
}

impl<'m> NetworkSimplex<'m> {
    pub fn new(metric: &'m CostMatrix, pivot_limit: usize) -> Self {
        Self { metric, pivot_limit }
    }

    /// Solve the transportation problem exactly.
    pub fn solve(&self, r: &Histogram, c: &Histogram) -> Result<TransportPlan, OtError> {
        let d = self.metric.dim();
        // Support restriction (Algorithm 1 line 1 analogue).
        let src_ids: Vec<usize> = r.support();
        let snk_ids: Vec<usize> = c.support();
        let m = src_ids.len();
        let n = snk_ids.len();
        debug_assert!(m > 0 && n > 0, "histograms have positive mass");

        // Perturbed marginals: strictly positive basic flows throughout.
        let mut supply: Vec<F> = src_ids.iter().map(|&i| r.values()[i]).collect();
        let mut demand: Vec<F> = snk_ids.iter().map(|&j| c.values()[j]).collect();
        for s in &mut supply {
            *s += DELTA;
        }
        demand[n - 1] += DELTA * m as F;

        let mut state = State::new(m, n);
        state.northwest_init(&supply, &demand);

        // Support-local cost accessor.
        let cost = |i: u32, j: u32| -> F {
            self.metric.get(src_ids[i as usize], snk_ids[j as usize])
        };

        let mut stats = SimplexStats { sources: m, sinks: n, ..Default::default() };
        state.rebuild_tree(&mut stats);
        state.recompute_potentials(&cost);

        // Block-search pricing state: wrapping cursor over m*n arcs.
        let num_arcs = m * n;
        let block = (num_arcs as f64).sqrt().ceil() as usize + 1;
        let mut cursor = 0usize;

        loop {
            // --- Pricing: find entering arc (most negative in a block). ---
            let mut best: Option<(u32, u32, F)> = None;
            let mut scanned = 0usize;
            while scanned < num_arcs {
                let end = (scanned + block).min(num_arcs);
                for _ in scanned..end {
                    let a = cursor;
                    cursor += 1;
                    if cursor == num_arcs {
                        cursor = 0;
                    }
                    let i = (a / n) as u32;
                    let j = (a % n) as u32;
                    let rc = cost(i, j) - state.pot_src[i as usize] - state.pot_snk[j as usize];
                    if rc < -PRICE_EPS {
                        match best {
                            Some((_, _, b)) if b <= rc => {}
                            _ => best = Some((i, j, rc)),
                        }
                    }
                }
                stats.arcs_priced += end - scanned;
                scanned = end;
                if best.is_some() {
                    break;
                }
            }
            let Some((ei, ej, _)) = best else {
                break; // dual feasible => optimal
            };

            // --- Ratio test along the tree cycle closed by (ei, ej). ---
            stats.pivots += 1;
            if stats.pivots > self.pivot_limit {
                return Err(OtError::PivotLimit(self.pivot_limit));
            }
            state.pivot(ei, ej, &cost, &mut stats);
        }

        // --- Exact re-solve of the final tree on unperturbed marginals. ---
        let exact_supply: Vec<F> = src_ids.iter().map(|&i| r.values()[i]).collect();
        let exact_demand: Vec<F> = snk_ids.iter().map(|&j| c.values()[j]).collect();
        state.resolve_tree_flows(&exact_supply, &exact_demand);

        // Assemble the plan in original (unrestricted) indices.
        let mut entries = Vec::with_capacity(m + n);
        let mut total_cost = 0.0;
        for arc in state.arcs.iter().filter(|a| a.alive) {
            let f = arc.flow.max(0.0);
            if f > 0.0 {
                let gi = src_ids[arc.src as usize];
                let gj = snk_ids[arc.snk as usize];
                entries.push((gi, gj, f));
                total_cost += f * self.metric.get(gi, gj);
            }
        }
        // Potentials in original index space (dropped bins get harmless
        // values: u_i = 0, v_j = min_i (m_ij - u_i) keeps dual feasibility).
        let mut u = vec![0.0; d];
        let mut v = vec![F::INFINITY; d];
        for (loc, &g) in src_ids.iter().enumerate() {
            u[g] = state.pot_src[loc];
        }
        for (loc, &g) in snk_ids.iter().enumerate() {
            v[g] = state.pot_snk[loc];
        }
        for j in 0..d {
            if v[j].is_infinite() {
                let mut best = F::INFINITY;
                for i in 0..d {
                    best = best.min(self.metric.get(i, j) - u[i]);
                }
                v[j] = best;
            }
        }
        for i in 0..d {
            if !src_ids.contains(&i) {
                // Dropped sources: u_i = min_j (m_ij - v_j).
                let mut best = F::INFINITY;
                for j in 0..d {
                    best = best.min(self.metric.get(i, j) - v[j]);
                }
                u[i] = best.min(0.0);
            }
        }

        Ok(TransportPlan {
            dim: d,
            entries,
            cost: total_cost,
            potentials: (u, v),
            stats,
        })
    }
}

/// Mutable simplex state over support-local indices.
struct State {
    m: usize,
    n: usize,
    /// All basic arcs ever created; `alive` marks current basis members.
    arcs: Vec<BasicArc>,
    /// node (0..m sources, m..m+n sinks) -> incident alive arc ids.
    adj: Vec<Vec<u32>>,
    /// Tree structure (recomputed per pivot): parent node and the arc to it.
    parent: Vec<i64>,
    parent_arc: Vec<u32>,
    depth: Vec<u32>,
    /// BFS order (root first) — reused for potential propagation.
    order: Vec<u32>,
    pot_src: Vec<F>,
    pot_snk: Vec<F>,
}

impl State {
    fn new(m: usize, n: usize) -> Self {
        Self {
            m,
            n,
            arcs: Vec::with_capacity(2 * (m + n)),
            adj: vec![Vec::new(); m + n],
            parent: vec![-1; m + n],
            parent_arc: vec![u32::MAX; m + n],
            depth: vec![0; m + n],
            order: Vec::with_capacity(m + n),
            pot_src: vec![0.0; m],
            pot_snk: vec![0.0; n],
        }
    }

    /// North-west corner initial basis: m+n-1 arcs forming a spanning tree.
    fn northwest_init(&mut self, supply: &[F], demand: &[F]) {
        let (m, n) = (self.m, self.n);
        let mut s = supply.to_vec();
        let mut dmd = demand.to_vec();
        let (mut i, mut j) = (0usize, 0usize);
        while i < m && j < n {
            let f = s[i].min(dmd[j]);
            self.add_arc(i as u32, j as u32, f);
            s[i] -= f;
            dmd[j] -= f;
            // With perturbed marginals exact ties are impossible except at
            // the very last cell; advance the exhausted side.
            if s[i] <= dmd[j] {
                i += 1;
            } else {
                j += 1;
            }
        }
        debug_assert_eq!(
            self.arcs.len(),
            m + n - 1,
            "NW corner must produce a spanning tree"
        );
    }

    fn add_arc(&mut self, src: u32, snk: u32, flow: F) -> u32 {
        let id = self.arcs.len() as u32;
        self.arcs.push(BasicArc { src, snk, flow, alive: true });
        self.adj[src as usize].push(id);
        self.adj[self.m + snk as usize].push(id);
        id
    }

    fn remove_arc(&mut self, id: u32) {
        let arc = self.arcs[id as usize];
        self.arcs[id as usize].alive = false;
        self.adj[arc.src as usize].retain(|&a| a != id);
        self.adj[self.m + arc.snk as usize].retain(|&a| a != id);
    }

    /// Other endpoint (node index) of arc `id` as seen from `node`.
    #[inline]
    fn other_end(&self, id: u32, node: u32) -> u32 {
        let arc = &self.arcs[id as usize];
        let s = arc.src;
        let t = self.m as u32 + arc.snk;
        if node == s {
            t
        } else {
            s
        }
    }

    /// BFS from node 0: fill parent / parent_arc / depth / order.
    fn rebuild_tree(&mut self, _stats: &mut SimplexStats) {
        let nn = self.m + self.n;
        self.order.clear();
        for p in &mut self.parent {
            *p = -2; // unvisited
        }
        self.parent[0] = -1;
        self.depth[0] = 0;
        self.order.push(0);
        let mut head = 0;
        while head < self.order.len() {
            let x = self.order[head];
            head += 1;
            for &aid in &self.adj[x as usize] {
                let y = self.other_end(aid, x);
                if self.parent[y as usize] == -2 {
                    self.parent[y as usize] = x as i64;
                    self.parent_arc[y as usize] = aid;
                    self.depth[y as usize] = self.depth[x as usize] + 1;
                    self.order.push(y);
                }
            }
        }
        debug_assert_eq!(self.order.len(), nn, "basis must span all nodes");
    }

    /// Propagate potentials along the BFS order: on a basic arc (i, j),
    /// u_i + v_j = m_ij, anchored at u(root)=0.
    fn recompute_potentials(&mut self, cost: &impl Fn(u32, u32) -> F) {
        self.pot_src[0] = 0.0;
        for idx in 1..self.order.len() {
            let x = self.order[idx];
            let aid = self.parent_arc[x as usize];
            let arc = self.arcs[aid as usize];
            let mij = cost(arc.src, arc.snk);
            if (x as usize) < self.m {
                // x is a source; parent is the sink side of the arc.
                self.pot_src[x as usize] = mij - self.pot_snk[arc.snk as usize];
            } else {
                self.pot_snk[x as usize - self.m] = mij - self.pot_src[arc.src as usize];
            }
        }
    }

    /// Execute one pivot with entering arc (ei, ej).
    fn pivot(
        &mut self,
        ei: u32,
        ej: u32,
        cost: &impl Fn(u32, u32) -> F,
        stats: &mut SimplexStats,
    ) {
        // Cycle: entering arc ei -> ej (+θ), then tree path from sink node
        // (m + ej) back to source node ei. Collect per-arc signs:
        // traversing a tree arc source->sink adds +θ, sink->source -θ.
        let mut x = self.m as u32 + ej; // walk from the sink side
        let mut y = ei; // and from the source side
        // Arcs on the cycle with their sign (+1 / -1).
        let mut cycle: Vec<(u32, i8)> = Vec::with_capacity(16);

        // Bring both walkers to equal depth.
        while self.depth[x as usize] > self.depth[y as usize] {
            let aid = self.parent_arc[x as usize];
            // j-side: traversal x -> parent(x).
            let sign = if (x as usize) < self.m { 1 } else { -1 };
            cycle.push((aid, sign));
            x = self.parent[x as usize] as u32;
        }
        while self.depth[y as usize] > self.depth[x as usize] {
            let aid = self.parent_arc[y as usize];
            // i-side: traversal parent(y) -> y (cycle runs toward ei).
            let sign = if (y as usize) < self.m { -1 } else { 1 };
            cycle.push((aid, sign));
            y = self.parent[y as usize] as u32;
        }
        while x != y {
            let aid_x = self.parent_arc[x as usize];
            let sign_x = if (x as usize) < self.m { 1 } else { -1 };
            cycle.push((aid_x, sign_x));
            x = self.parent[x as usize] as u32;
            let aid_y = self.parent_arc[y as usize];
            let sign_y = if (y as usize) < self.m { -1 } else { 1 };
            cycle.push((aid_y, sign_y));
            y = self.parent[y as usize] as u32;
        }

        // Ratio test over the -θ arcs.
        let mut theta = F::INFINITY;
        let mut leaving: u32 = u32::MAX;
        for &(aid, sign) in &cycle {
            if sign < 0 {
                let f = self.arcs[aid as usize].flow;
                if f < theta {
                    theta = f;
                    leaving = aid;
                }
            }
        }
        debug_assert!(leaving != u32::MAX, "cycle must contain a leaving arc");

        // Apply flow change and swap basis arcs.
        for &(aid, sign) in &cycle {
            let a = &mut self.arcs[aid as usize];
            if sign > 0 {
                a.flow += theta;
            } else {
                a.flow -= theta;
            }
        }
        self.remove_arc(leaving);
        self.add_arc(ei, ej, theta);

        // Refresh tree + potentials (O(m+n)).
        self.rebuild_tree(stats);
        self.recompute_potentials(cost);
    }

    /// Given the final spanning tree, recompute its flows exactly for the
    /// *unperturbed* marginals by leaf elimination (unique tree solution).
    fn resolve_tree_flows(&mut self, supply: &[F], demand: &[F]) {
        let nn = self.m + self.n;
        // Net imbalance per node: + for sources, - for sinks.
        let mut bal = vec![0.0; nn];
        bal[..self.m].copy_from_slice(supply);
        for j in 0..self.n {
            bal[self.m + j] = -demand[j];
        }
        // Process nodes deepest-first: each non-root node's parent arc
        // carries exactly its subtree imbalance.
        for idx in (1..self.order.len()).rev() {
            let x = self.order[idx];
            let aid = self.parent_arc[x as usize];
            let arc = self.arcs[aid as usize];
            let is_source = (x as usize) < self.m;
            // Arc direction is src -> snk; flow = mass leaving the source
            // side. If x is the source endpoint, flow = +bal[x]; if x is
            // the sink endpoint, flow = -bal[x].
            let f = if is_source { bal[x as usize] } else { -bal[x as usize] };
            self.arcs[aid as usize].flow = f;
            let p = self.parent[x as usize] as usize;
            bal[p] += bal[x as usize];
            bal[x as usize] = 0.0;
            let _ = arc;
        }
        debug_assert!(
            bal[0].abs() < 1e-6,
            "tree flow conservation violated: residual {}",
            bal[0]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::{CostMatrix, GridMetric, RandomMetric};
    use crate::ot::EmdSolver;
    use crate::simplex::seeded_rng;

    fn assert_valid_optimal(plan: &TransportPlan, m: &CostMatrix, r: &Histogram, c: &Histogram) {
        // Primal feasibility: exact marginals.
        let rm = plan.row_marginal();
        let cm = plan.col_marginal();
        for (got, want) in rm.iter().zip(r.values()) {
            assert!((got - want).abs() < 1e-9, "row marginal {got} vs {want}");
        }
        for (got, want) in cm.iter().zip(c.values()) {
            assert!((got - want).abs() < 1e-9, "col marginal {got} vs {want}");
        }
        // Non-negativity.
        assert!(plan.entries.iter().all(|&(_, _, f)| f >= -1e-12));
        // Dual feasibility => optimality certificate.
        assert!(
            plan.dual_violation(m) < 1e-7,
            "dual violation {}",
            plan.dual_violation(m)
        );
        // Complementary slackness: cost equals dual objective u'r + v'c.
        let (u, v) = &plan.potentials;
        let dual: F = u.iter().zip(r.values()).map(|(a, b)| a * b).sum::<F>()
            + v.iter().zip(c.values()).map(|(a, b)| a * b).sum::<F>();
        assert!(
            (plan.cost - dual).abs() < 1e-7,
            "strong duality gap: primal {} dual {}",
            plan.cost,
            dual
        );
    }

    #[test]
    fn two_point_problem() {
        // All mass moves from bin 0 to bin 1 at cost 1.
        let m = CostMatrix::from_rows(2, vec![0., 1., 1., 0.]);
        let r = Histogram::dirac(2, 0);
        let c = Histogram::dirac(2, 1);
        let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
        assert!((plan.cost - 1.0).abs() < 1e-12);
        assert_valid_optimal(&plan, &m, &r, &c);
    }

    #[test]
    fn textbook_transportation_instance() {
        // Classic 3x3 with known optimum.
        let m = CostMatrix::from_rows(
            3,
            vec![4., 6., 8., 5., 3., 7., 6., 5., 2.],
        );
        let r = Histogram::from_weights(&[0.3, 0.4, 0.3]).unwrap();
        let c = Histogram::from_weights(&[0.3, 0.35, 0.35]).unwrap();
        let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
        assert_valid_optimal(&plan, &m, &r, &c);
        // Certificate above plus a hand-check: the optimum assigns
        // r0->c0 (cost 4, mass .3), r1->c1 (3, .35), r2->c2 (2, .3) and
        // routes r1's residual .05 to c2 (cost 7).
        let want = 0.3 * 4.0 + 0.35 * 3.0 + 0.3 * 2.0 + 0.05 * 7.0;
        assert!((plan.cost - want).abs() < 1e-9, "cost {}", plan.cost);
    }

    #[test]
    fn support_restriction_handles_zeros() {
        let m = GridMetric::new(2, 2).cost_matrix();
        let r = Histogram::from_weights(&[0.5, 0.0, 0.5, 0.0]).unwrap();
        let c = Histogram::from_weights(&[0.0, 0.5, 0.0, 0.5]).unwrap();
        let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
        assert_valid_optimal(&plan, &m, &r, &c);
        assert_eq!(plan.stats.sources, 2);
        assert_eq!(plan.stats.sinks, 2);
    }

    #[test]
    fn matches_1d_closed_form() {
        // Line metric: EMD has the CDF-difference closed form — an
        // independent oracle for the simplex.
        let d = 16;
        let mut data = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                data[i * d + j] = (i as F - j as F).abs();
            }
        }
        let m = CostMatrix::from_rows(d, data);
        let mut rng = seeded_rng(33);
        for _ in 0..10 {
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
            let want = crate::ot::onedim::emd_1d(r.values(), c.values());
            assert!(
                (plan.cost - want).abs() < 1e-9,
                "simplex {} vs 1d closed form {}",
                plan.cost,
                want
            );
            assert_valid_optimal(&plan, &m, &r, &c);
        }
    }

    #[test]
    fn vertex_support_bound() {
        // Optimal vertices have at most 2d-1 nonzeros (§3.1).
        let mut rng = seeded_rng(5);
        let m = RandomMetric::new(20).sample(&mut rng);
        let r = Histogram::sample_uniform(20, &mut rng);
        let c = Histogram::sample_uniform(20, &mut rng);
        let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
        assert!(plan.support_size() <= 2 * 20 - 1);
        assert_valid_optimal(&plan, &m, &r, &c);
    }

    #[test]
    fn triangle_inequality_of_emd() {
        // d_M is a distance when M is a metric (paper §2.2).
        let mut rng = seeded_rng(8);
        let m = GridMetric::new(3, 3).cost_matrix();
        for _ in 0..5 {
            let x = Histogram::sample_uniform(9, &mut rng);
            let y = Histogram::sample_uniform(9, &mut rng);
            let z = Histogram::sample_uniform(9, &mut rng);
            let solver = EmdSolver::new(&m);
            let dxy = solver.solve(&x, &y).unwrap().cost;
            let dyz = solver.solve(&y, &z).unwrap().cost;
            let dxz = solver.solve(&x, &z).unwrap().cost;
            assert!(dxz <= dxy + dyz + 1e-9);
        }
    }

    /// Random instances: certificate-checked optimality end to end.
    #[test]
    fn prop_random_instances_are_certified() {
        for seed in 0..24u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 24);
            let m = RandomMetric::new(d).sample(&mut rng);
            let r = Histogram::sample_dirichlet(d, 0.7, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let plan = EmdSolver::new(&m).solve(&r, &c).unwrap();
            assert_valid_optimal(&plan, &m, &r, &c);
        }
    }

    /// Symmetry d_M(r,c) = d_M(c,r) for symmetric M.
    #[test]
    fn prop_emd_is_symmetric() {
        for seed in 100..124u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 16);
            let m = RandomMetric::new(d).sample(&mut rng);
            let r = Histogram::sample_uniform(d, &mut rng);
            let c = Histogram::sample_uniform(d, &mut rng);
            let solver = EmdSolver::new(&m);
            let ab = solver.solve(&r, &c).unwrap().cost;
            let ba = solver.solve(&c, &r).unwrap().cost;
            assert!((ab - ba).abs() < 1e-8, "{ab} vs {ba}");
        }
    }
}
