//! Exact optimal transportation (the paper's baseline, §2.2).
//!
//! Computes d_M(r,c) = min_{P ∈ U(r,c)} ⟨P, M⟩ with a transportation
//! network simplex — the same algorithm family as Rubner's `emd_mex` and
//! the network-simplex codes the paper benchmarks against in §5.3. This is
//! the substrate for:
//!
//! * the EMD row of Figure 2 (MNIST classification),
//! * the denominators of Figure 3 (the (d^λ − d_M)/d_M gap study),
//! * the "EMD solver" series of Figure 4 (super-cubic wallclock growth).
//!
//! [`onedim`] additionally provides the closed-form 1-D solution (CDF
//! difference), used both as an independent correctness oracle for the
//! simplex and as a fast path for line metrics.

mod network_simplex;
pub mod onedim;

pub use network_simplex::{NetworkSimplex, SimplexStats};

use crate::metric::CostMatrix;
use crate::simplex::Histogram;
use crate::F;

/// Errors from the exact solver.
#[derive(Debug, Clone, PartialEq)]
pub enum OtError {
    DimensionMismatch(usize, usize),
    PivotLimit(usize),
}

impl std::fmt::Display for OtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OtError::DimensionMismatch(got, want) => write!(
                f,
                "histogram dimension {got} does not match cost matrix dimension {want}"
            ),
            OtError::PivotLimit(limit) => {
                write!(f, "network simplex exceeded the pivot limit ({limit})")
            }
        }
    }
}

impl std::error::Error for OtError {}

/// An optimal (or feasible) transportation plan in sparse triplet form.
///
/// Vertices of U(r,c) have at most `sup(r)+sup(c)-1` nonzero entries
/// (Brualdi, §8.1.3) — the "quasi-deterministic" plans of §3.1 — so sparse
/// storage is exact, not an approximation.
#[derive(Debug, Clone)]
pub struct TransportPlan {
    /// Problem dimension (plans are conceptually d×d).
    pub dim: usize,
    /// Nonzero entries (i, j, mass).
    pub entries: Vec<(usize, usize, F)>,
    /// Objective value ⟨P, M⟩.
    pub cost: F,
    /// Dual potentials (u over rows, v over columns) certifying
    /// optimality: m_ij − u_i − v_j ≥ 0 for all arcs.
    pub potentials: (Vec<F>, Vec<F>),
    /// Solver statistics (pivot count etc.).
    pub stats: SimplexStats,
}

impl TransportPlan {
    /// Densify to a row-major d×d matrix.
    pub fn to_dense(&self) -> Vec<F> {
        let mut p = vec![0.0; self.dim * self.dim];
        for &(i, j, f) in &self.entries {
            p[i * self.dim + j] += f;
        }
        p
    }

    /// Row marginal Σ_j P_ij.
    pub fn row_marginal(&self) -> Vec<F> {
        let mut r = vec![0.0; self.dim];
        for &(i, _, f) in &self.entries {
            r[i] += f;
        }
        r
    }

    /// Column marginal Σ_i P_ij.
    pub fn col_marginal(&self) -> Vec<F> {
        let mut c = vec![0.0; self.dim];
        for &(_, j, f) in &self.entries {
            c[j] += f;
        }
        c
    }

    /// Entropy h(P) of the plan (0·log 0 = 0).
    pub fn entropy(&self) -> F {
        self.entries
            .iter()
            .filter(|&&(_, _, f)| f > 0.0)
            .map(|&(_, _, f)| -f * f.ln())
            .sum()
    }

    /// Number of strictly positive entries — ≤ 2d−1 at a vertex.
    pub fn support_size(&self) -> usize {
        self.entries.iter().filter(|&&(_, _, f)| f > 0.0).count()
    }

    /// Max dual-feasibility violation max_ij (u_i + v_j − m_ij)₊: an
    /// independent optimality certificate (0 ⇒ the plan is optimal).
    pub fn dual_violation(&self, m: &CostMatrix) -> F {
        let (u, v) = &self.potentials;
        let mut worst: F = 0.0;
        for i in 0..self.dim {
            let row = m.row(i);
            for j in 0..self.dim {
                worst = worst.max(u[i] + v[j] - row[j]);
            }
        }
        worst.max(0.0)
    }
}

/// High-level exact EMD solver bound to a cost matrix.
#[derive(Debug, Clone)]
pub struct EmdSolver<'m> {
    metric: &'m CostMatrix,
    pivot_limit: usize,
}

impl<'m> EmdSolver<'m> {
    /// Bind to a ground cost matrix. A generous default pivot limit guards
    /// against (theoretically impossible, numerically conceivable) cycling.
    pub fn new(metric: &'m CostMatrix) -> Self {
        let d = metric.dim();
        Self { metric, pivot_limit: 200 * d * d + 10_000 }
    }

    /// Override the pivot limit.
    pub fn with_pivot_limit(mut self, limit: usize) -> Self {
        self.pivot_limit = limit;
        self
    }

    /// Solve d_M(r, c) exactly. Zero-mass bins are dropped internally
    /// (Algorithm 1 line 1 of the paper does the same for Sinkhorn).
    pub fn solve(&self, r: &Histogram, c: &Histogram) -> Result<TransportPlan, OtError> {
        let d = self.metric.dim();
        if r.dim() != d {
            return Err(OtError::DimensionMismatch(r.dim(), d));
        }
        if c.dim() != d {
            return Err(OtError::DimensionMismatch(c.dim(), d));
        }
        NetworkSimplex::new(self.metric, self.pivot_limit).solve(r, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::GridMetric;
    use crate::simplex::seeded_rng;

    #[test]
    fn plan_accessors() {
        let plan = TransportPlan {
            dim: 2,
            entries: vec![(0, 0, 0.5), (1, 1, 0.25), (1, 0, 0.25)],
            cost: 0.25,
            potentials: (vec![0.0; 2], vec![0.0; 2]),
            stats: SimplexStats::default(),
        };
        assert_eq!(plan.to_dense(), vec![0.5, 0.0, 0.25, 0.25]);
        assert_eq!(plan.row_marginal(), vec![0.5, 0.5]);
        assert_eq!(plan.col_marginal(), vec![0.75, 0.25]);
        assert_eq!(plan.support_size(), 3);
        assert!(plan.entropy() > 0.0);
    }

    #[test]
    fn dimension_mismatch_detected() {
        let m = GridMetric::new(2, 2).cost_matrix();
        let solver = EmdSolver::new(&m);
        let r = Histogram::uniform(3);
        let c = Histogram::uniform(4);
        assert!(matches!(
            solver.solve(&r, &c),
            Err(OtError::DimensionMismatch(3, 4))
        ));
    }

    #[test]
    fn identical_histograms_cost_zero() {
        let m = GridMetric::new(3, 3).cost_matrix();
        let mut rng = seeded_rng(1);
        let r = Histogram::sample_uniform(9, &mut rng);
        let plan = EmdSolver::new(&m).solve(&r, &r).unwrap();
        assert!(plan.cost.abs() < 1e-12, "d_M(r,r) = {}", plan.cost);
    }
}
