//! Sampling on the probability simplex.
//!
//! The paper's speed experiments (§5.3–§5.4) "generate points uniformly in
//! the d-simplex (Smith and Tromble, 2004)". The exponential-spacings
//! construction used here is the standard equivalent: d i.i.d. Exp(1)
//! variables normalized by their sum are uniform on Σ_d (it is the
//! continuous analogue of Smith & Tromble's sorted-uniform gaps and avoids
//! the O(d log d) sort).

use crate::rng::Rng;
use crate::F;

/// Deterministic RNG for reproducible experiments; every harness and test
/// in this crate derives its randomness from a seed through here.
pub fn seeded_rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

/// Draw one point uniformly at random from the simplex Σ_d.
pub fn sample_uniform_simplex(d: usize, rng: &mut Rng) -> Vec<F> {
    assert!(d > 0, "dimension must be positive");
    let mut v: Vec<F> = (0..d)
        .map(|_| {
            // Inverse-CDF Exp(1); guard the log away from 0.
            let u: F = rng.f64().max(1e-300);
            -u.ln()
        })
        .collect();
    let total: F = v.iter().sum();
    for x in &mut v {
        *x /= total;
    }
    v
}

/// Draw from a symmetric Dirichlet(alpha) via Gamma(alpha, 1)
/// normalization — spikier (α<1) or flatter (α>1) than uniform sampling.
pub fn sample_dirichlet(d: usize, alpha: F, rng: &mut Rng) -> Vec<F> {
    assert!(d > 0, "dimension must be positive");
    assert!(alpha > 0.0, "alpha must be positive");
    let mut v: Vec<F> = (0..d).map(|_| rng.gamma(alpha)).collect();
    let mut total: F = v.iter().sum();
    if total <= 0.0 {
        // Pathologically tiny alpha: fall back to a random dirac.
        let i = rng.below(d);
        v = vec![0.0; d];
        v[i] = 1.0;
        total = 1.0;
    }
    for x in &mut v {
        *x /= total;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_simplex_moments() {
        // Coordinates of a uniform simplex point have mean 1/d; spot-check
        // the empirical mean over many draws.
        let mut rng = seeded_rng(42);
        let d = 10;
        let trials = 4000;
        let mut mean = vec![0.0; d];
        for _ in 0..trials {
            let v = sample_uniform_simplex(d, &mut rng);
            for (m, x) in mean.iter_mut().zip(&v) {
                *m += x / trials as F;
            }
        }
        for m in &mean {
            assert!((m - 0.1).abs() < 0.01, "biased coordinate mean {m}");
        }
    }

    #[test]
    fn uniform_simplex_second_moment() {
        // E[x_i^2] = 2/(d(d+1)) under the flat Dirichlet.
        let mut rng = seeded_rng(7);
        let d = 5;
        let trials = 20000;
        let mut m2 = 0.0;
        for _ in 0..trials {
            let v = sample_uniform_simplex(d, &mut rng);
            m2 += v[0] * v[0] / trials as F;
        }
        let want = 2.0 / (d as F * (d as F + 1.0));
        assert!(
            (m2 - want).abs() < 0.1 * want,
            "E[x^2]: got {m2}, want {want}"
        );
    }

    #[test]
    fn dirichlet_concentration() {
        // Large alpha concentrates near uniform; small alpha is spiky.
        let mut rng = seeded_rng(3);
        let flat = sample_dirichlet(20, 100.0, &mut rng);
        let spiky = sample_dirichlet(20, 0.05, &mut rng);
        let ent = |v: &[F]| crate::simplex::entropy(v);
        assert!(ent(&flat) > ent(&spiky));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sample_uniform_simplex(8, &mut seeded_rng(5));
        let b = sample_uniform_simplex(8, &mut seeded_rng(5));
        assert_eq!(a, b);
    }

    #[test]
    fn all_samples_normalized() {
        let mut rng = seeded_rng(9);
        for d in [1usize, 2, 7, 100] {
            for _ in 0..20 {
                let v = sample_uniform_simplex(d, &mut rng);
                assert!((v.iter().sum::<F>() - 1.0).abs() < 1e-12);
                assert!(v.iter().all(|&x| x >= 0.0));
                let w = sample_dirichlet(d, 0.4, &mut rng);
                assert!((w.iter().sum::<F>() - 1.0).abs() < 1e-12);
            }
        }
    }
}
