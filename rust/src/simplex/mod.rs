//! Probability-simplex primitives: histograms, information measures and
//! uniform sampling.
//!
//! Everything in the paper lives on the simplex Σ_d = {x ∈ R₊^d : Σx = 1}:
//! the histograms being compared, the transportation polytope's marginals,
//! and the entropic quantities (h, KL, mutual information) that define the
//! Sinkhorn ball U_α(r, c). This module is the shared foundation.

mod info;
mod sampling;

pub use info::{entropy, independence_table, kl_divergence, mutual_information};
pub use sampling::{sample_dirichlet, sample_uniform_simplex, seeded_rng};

use crate::F;

/// A probability histogram: a non-negative vector summing to one.
///
/// Invariants are enforced at construction: values are finite,
/// non-negative, and normalized (to within an absolute drift of 1e-9,
/// re-normalized on entry otherwise).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    values: Vec<F>,
}

/// Error raised when a vector cannot be interpreted as a histogram.
#[derive(Debug, Clone, PartialEq)]
pub enum HistogramError {
    Empty,
    Invalid(usize, F),
    ZeroMass,
}

impl std::fmt::Display for HistogramError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HistogramError::Empty => write!(f, "histogram must be non-empty"),
            HistogramError::Invalid(i, v) => write!(
                f,
                "histogram entries must be finite and non-negative (index {i}: {v})"
            ),
            HistogramError::ZeroMass => {
                write!(f, "histogram must have positive total mass")
            }
        }
    }
}

impl std::error::Error for HistogramError {}

impl Histogram {
    /// Build a histogram from raw non-negative weights, normalizing them.
    pub fn from_weights(weights: &[F]) -> Result<Self, HistogramError> {
        if weights.is_empty() {
            return Err(HistogramError::Empty);
        }
        for (i, &w) in weights.iter().enumerate() {
            if !w.is_finite() || w < 0.0 {
                return Err(HistogramError::Invalid(i, w));
            }
        }
        let total: F = weights.iter().sum();
        if total <= 0.0 {
            return Err(HistogramError::ZeroMass);
        }
        Ok(Self { values: weights.iter().map(|w| w / total).collect() })
    }

    /// The uniform histogram 1/d.
    pub fn uniform(d: usize) -> Self {
        assert!(d > 0, "dimension must be positive");
        Self { values: vec![1.0 / d as F; d] }
    }

    /// A point mass δ_i in dimension d.
    pub fn dirac(d: usize, i: usize) -> Self {
        assert!(i < d, "dirac index out of range");
        let mut values = vec![0.0; d];
        values[i] = 1.0;
        Self { values }
    }

    /// Sample uniformly from the simplex (Smith & Tromble, 2004) — the
    /// workload generator of the paper's §5.3/§5.4 speed experiments.
    pub fn sample_uniform(d: usize, rng: &mut crate::rng::Rng) -> Self {
        Self { values: sample_uniform_simplex(d, rng) }
    }

    /// Sample from a symmetric Dirichlet(α) — spikier (α<1) or flatter
    /// (α>1) histograms than uniform-simplex sampling.
    pub fn sample_dirichlet(d: usize, alpha: F, rng: &mut crate::rng::Rng) -> Self {
        Self { values: sample_dirichlet(d, alpha, rng) }
    }

    /// Dimension d of the ambient simplex.
    #[inline]
    pub fn dim(&self) -> usize {
        self.values.len()
    }

    /// Histogram entries (guaranteed normalized, non-negative).
    #[inline]
    pub fn values(&self) -> &[F] {
        &self.values
    }

    /// Shannon entropy h(r) in nats.
    pub fn entropy(&self) -> F {
        entropy(&self.values)
    }

    /// Number of strictly positive entries (the support size).
    pub fn support_size(&self) -> usize {
        self.values.iter().filter(|&&v| v > 0.0).count()
    }

    /// Indices of strictly positive entries — Algorithm 1 line 1 of the
    /// paper drops zero-mass source bins before scaling.
    pub fn support(&self) -> Vec<usize> {
        (0..self.dim()).filter(|&i| self.values[i] > 0.0).collect()
    }

    /// Entries converted to f32 for the XLA/PJRT boundary.
    pub fn to_f32(&self) -> Vec<f32> {
        self.values.iter().map(|&v| v as f32).collect()
    }

    /// Mix with the uniform histogram: (1-eps) r + eps/d. Used to give
    /// full support to empirical histograms before entropic scaling.
    pub fn smooth(&self, eps: F) -> Self {
        let d = self.dim() as F;
        let values =
            self.values.iter().map(|&v| (1.0 - eps) * v + eps / d).collect();
        Self { values }
    }

    /// Total-mass drift from 1 (diagnostic; should be ~1e-16).
    pub fn mass_error(&self) -> F {
        (self.values.iter().sum::<F>() - 1.0).abs()
    }
}

impl AsRef<[F]> for Histogram {
    fn as_ref(&self) -> &[F] {
        &self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_weights_normalizes() {
        let h = Histogram::from_weights(&[2.0, 2.0, 4.0]).unwrap();
        assert_eq!(h.values(), &[0.25, 0.25, 0.5]);
        assert!(h.mass_error() < 1e-12);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert_eq!(Histogram::from_weights(&[]), Err(HistogramError::Empty));
        assert_eq!(
            Histogram::from_weights(&[0.0, 0.0]),
            Err(HistogramError::ZeroMass)
        );
        assert!(matches!(
            Histogram::from_weights(&[1.0, -0.5]),
            Err(HistogramError::Invalid(1, _))
        ));
        assert!(matches!(
            Histogram::from_weights(&[1.0, F::NAN]),
            Err(HistogramError::Invalid(1, _))
        ));
    }

    #[test]
    fn uniform_and_dirac() {
        let u = Histogram::uniform(4);
        assert_eq!(u.values(), &[0.25; 4]);
        assert!((u.entropy() - (4.0 as F).ln()).abs() < 1e-12);
        let d = Histogram::dirac(3, 1);
        assert_eq!(d.values(), &[0.0, 1.0, 0.0]);
        assert_eq!(d.entropy(), 0.0);
        assert_eq!(d.support(), vec![1]);
        assert_eq!(d.support_size(), 1);
    }

    #[test]
    fn smooth_gives_full_support() {
        let d = Histogram::dirac(5, 0).smooth(0.1);
        assert_eq!(d.support_size(), 5);
        assert!(d.mass_error() < 1e-12);
    }

    // Property-style sweeps (in-tree harness; see README.md on the
    // offline dependency policy).
    #[test]
    fn prop_sampled_histograms_are_valid() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(1, 200);
            let h = Histogram::sample_uniform(d, &mut rng);
            assert_eq!(h.dim(), d);
            assert!(h.mass_error() < 1e-9);
            assert!(h.values().iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn prop_entropy_bounded_by_log_d() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(1, 100);
            let h = Histogram::sample_uniform(d, &mut rng);
            let e = h.entropy();
            assert!(e >= -1e-12);
            assert!(e <= (d as F).ln() + 1e-9);
        }
    }

    #[test]
    fn prop_normalization_is_scale_invariant() {
        for seed in 0..100u64 {
            let mut rng = seeded_rng(seed);
            let n = rng.range_usize(1, 50);
            let w: Vec<F> = (0..n).map(|_| rng.range_f64(1e-6, 1e6)).collect();
            let s = rng.range_f64(1e-3, 1e3);
            let a = Histogram::from_weights(&w).unwrap();
            let scaled: Vec<F> = w.iter().map(|x| x * s).collect();
            let b = Histogram::from_weights(&scaled).unwrap();
            for (x, y) in a.values().iter().zip(b.values()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }
}
