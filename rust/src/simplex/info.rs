//! Information-theoretic measures over histograms and joint tables.
//!
//! These implement the quantities of the paper's §2.1/§3.1: entropy h(·),
//! Kullback–Leibler divergence, the independence table rcᵀ and the
//! identity KL(P ‖ rcᵀ) = h(r) + h(c) − h(P) = I(X;Y) that defines the
//! entropic ball U_α(r, c).

use crate::F;

/// Shannon entropy −Σ p log p in nats, with 0·log 0 = 0.
pub fn entropy(p: &[F]) -> F {
    p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| -x * x.ln())
        .sum()
}

/// KL(p ‖ q) = Σ p log(p/q), +∞ when supp(p) ⊄ supp(q).
pub fn kl_divergence(p: &[F], q: &[F]) -> F {
    assert_eq!(p.len(), q.len(), "KL arguments must have equal length");
    let mut acc = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            if qi <= 0.0 {
                return F::INFINITY;
            }
            acc += pi * (pi / qi).ln();
        }
    }
    acc
}

/// The independence table rcᵀ flattened row-major: the max-entropy element
/// of U(r, c) (Good, 1963), center of the KL ball in Figure 1.
pub fn independence_table(r: &[F], c: &[F]) -> Vec<F> {
    let mut table = Vec::with_capacity(r.len() * c.len());
    for &ri in r {
        for &cj in c {
            table.push(ri * cj);
        }
    }
    table
}

/// Mutual information I(X;Y) of a joint table P (row-major, rows = X) —
/// equals KL(P ‖ rcᵀ) where (r, c) are P's marginals.
pub fn mutual_information(p: &[F], d_rows: usize, d_cols: usize) -> F {
    assert_eq!(p.len(), d_rows * d_cols, "table shape mismatch");
    let mut r = vec![0.0; d_rows];
    let mut c = vec![0.0; d_cols];
    for i in 0..d_rows {
        for j in 0..d_cols {
            let pij = p[i * d_cols + j];
            r[i] += pij;
            c[j] += pij;
        }
    }
    let mut acc = 0.0;
    for i in 0..d_rows {
        for j in 0..d_cols {
            let pij = p[i * d_cols + j];
            if pij > 0.0 {
                acc += pij * (pij / (r[i] * c[j])).ln();
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::{seeded_rng, Histogram};

    #[test]
    fn entropy_edge_cases() {
        assert_eq!(entropy(&[1.0, 0.0]), 0.0);
        assert!((entropy(&[0.5, 0.5]) - (2.0 as F).ln()).abs() < 1e-12);
    }

    #[test]
    fn kl_of_identical_is_zero() {
        let p = [0.2, 0.3, 0.5];
        assert!(kl_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn kl_infinite_outside_support() {
        assert_eq!(kl_divergence(&[0.5, 0.5], &[1.0, 0.0]), F::INFINITY);
    }

    #[test]
    fn independence_table_marginals_and_entropy() {
        // h(rc^T) = h(r) + h(c): the inequality (1) of the paper is tight.
        let r = [0.3, 0.7];
        let c = [0.25, 0.25, 0.5];
        let t = independence_table(&r, &c);
        let row: F = t[..3].iter().sum();
        assert!((row - r[0]).abs() < 1e-12);
        assert!((entropy(&t) - (entropy(&r) + entropy(&c))).abs() < 1e-12);
        // ...and its mutual information is exactly zero.
        assert!(mutual_information(&t, 2, 3).abs() < 1e-12);
    }

    /// KL(P || rc^T) = h(r) + h(c) - h(P) for arbitrary joint tables
    /// (the identity the Sinkhorn ball U_alpha is built on).
    #[test]
    fn prop_kl_entropy_identity() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(2, 12);
            // Random joint table with full support.
            let p_h = Histogram::sample_dirichlet(d * d, 1.0, &mut rng);
            let p = p_h.values();
            let mut r = vec![0.0; d];
            let mut c = vec![0.0; d];
            for i in 0..d {
                for j in 0..d {
                    r[i] += p[i * d + j];
                    c[j] += p[i * d + j];
                }
            }
            let indep = independence_table(&r, &c);
            let lhs = kl_divergence(p, &indep);
            let rhs = entropy(&r) + entropy(&c) - entropy(p);
            assert!((lhs - rhs).abs() < 1e-9, "identity violated: {lhs} vs {rhs}");
            // Inequality (1): h(P) <= h(r) + h(c).
            assert!(entropy(p) <= entropy(&r) + entropy(&c) + 1e-9);
            // Mutual information agrees with the KL form.
            assert!((mutual_information(p, d, d) - lhs).abs() < 1e-9);
        }
    }

    #[test]
    fn prop_kl_nonnegative() {
        for seed in 0..200u64 {
            let mut rng = seeded_rng(seed);
            let d = rng.range_usize(1, 30);
            let p = Histogram::sample_uniform(d, &mut rng);
            let q = Histogram::sample_dirichlet(d, 0.5, &mut rng).smooth(1e-6);
            assert!(kl_divergence(p.values(), q.values()) >= -1e-12);
        }
    }
}
