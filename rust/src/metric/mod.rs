//! Ground cost matrices — the distance's *parameter* (paper §2.2).
//!
//! The ground metric M ∈ M (the cone of metric matrices: zero diagonal,
//! symmetry, triangle inequalities) is what distinguishes transportation
//! distances from every other divergence on the simplex. This module
//! provides the paper's three constructions plus validation utilities:
//!
//! * [`GridMetric`] — Euclidean distances between pixel positions on an
//!   H×W grid (the MNIST experiment's 400×400 matrix, §5.1.2);
//! * [`RandomMetric`] — distances between d Gaussian points in R^{d/10},
//!   median-normalized (the speed experiments' workload, §5.3);
//! * element-wise powers M^a (Euclidean distance matrices stay Euclidean
//!   for 0 < a < 1 — used by the Independence kernel, §5.1.2).

mod validate;

pub use validate::{is_metric_matrix, max_triangle_violation, MetricViolation};

use crate::linalg::median;
use crate::rng::Rng;
use crate::F;

/// A dense, symmetric, zero-diagonal cost matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct CostMatrix {
    d: usize,
    /// Row-major d×d buffer.
    data: Vec<F>,
}

impl CostMatrix {
    /// Build from a row-major buffer, checking basic shape sanity
    /// (square, finite, non-negative). Metric-cone membership is *not*
    /// enforced here — use [`is_metric_matrix`] when it matters.
    pub fn from_rows(d: usize, data: Vec<F>) -> Self {
        assert_eq!(data.len(), d * d, "cost matrix must be d*d");
        assert!(
            data.iter().all(|v| v.is_finite() && *v >= 0.0),
            "cost entries must be finite and non-negative"
        );
        Self { d, data }
    }

    /// Dimension d (matrix is d×d).
    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> F {
        debug_assert!(i < self.d && j < self.d);
        self.data[i * self.d + j]
    }

    /// Contiguous row i.
    #[inline]
    pub fn row(&self, i: usize) -> &[F] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Row-major buffer.
    #[inline]
    pub fn data(&self) -> &[F] {
        &self.data
    }

    /// f32 copy for the XLA/PJRT boundary.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Elementwise power M^a. For Euclidean distance matrices and
    /// 0 < a ≤ 1 the result is again a Euclidean distance matrix
    /// (Berg et al., 1984 — footnote 1 of the paper).
    pub fn powf(&self, a: F) -> CostMatrix {
        CostMatrix {
            d: self.d,
            data: self.data.iter().map(|&v| v.powf(a)).collect(),
        }
    }

    /// Divide by the median of the off-diagonal entries — the paper's
    /// `M = M / median(M(:))` normalization (§5.3). No-op on an all-zero
    /// matrix.
    pub fn median_normalized(&self) -> CostMatrix {
        let off: Vec<F> = (0..self.d)
            .flat_map(|i| (0..self.d).filter(move |&j| j != i).map(move |j| self.get(i, j)))
            .collect();
        if off.is_empty() {
            return self.clone();
        }
        let med = median(&off);
        if med <= 0.0 {
            return self.clone();
        }
        CostMatrix { d: self.d, data: self.data.iter().map(|&v| v / med).collect() }
    }

    /// Median of off-diagonal entries (the paper's q50(M), the unit for
    /// the λ grid {5,7,9,11}/q50(M) in §5.1.2).
    pub fn median_cost(&self) -> F {
        let off: Vec<F> = (0..self.d)
            .flat_map(|i| (0..self.d).filter(move |&j| j != i).map(move |j| self.get(i, j)))
            .collect();
        if off.is_empty() {
            0.0
        } else {
            median(&off)
        }
    }

    /// Largest entry (governs exp(-λM) underflow, see sinkhorn::log_domain).
    pub fn max_cost(&self) -> F {
        self.data.iter().cloned().fold(0.0, F::max)
    }

    /// The transportation cost of a full plan: ⟨P, M⟩.
    pub fn plan_cost(&self, plan: &[F]) -> F {
        assert_eq!(plan.len(), self.d * self.d, "plan must be d*d");
        crate::linalg::dot(&self.data, plan)
    }
}

/// Euclidean distances between the points of an H×W pixel grid: the
/// natural ground metric for images (paper §5.1, d = H·W = 400 for MNIST).
#[derive(Debug, Clone, Copy)]
pub struct GridMetric {
    height: usize,
    width: usize,
}

impl GridMetric {
    pub fn new(height: usize, width: usize) -> Self {
        assert!(height > 0 && width > 0);
        Self { height, width }
    }

    /// Histogram dimension d = H·W.
    pub fn dim(&self) -> usize {
        self.height * self.width
    }

    /// The d×d matrix of Euclidean distances between pixel centers
    /// (row-major pixel order).
    pub fn cost_matrix(&self) -> CostMatrix {
        let d = self.dim();
        let mut data = vec![0.0; d * d];
        for a in 0..d {
            let (ya, xa) = (a / self.width, a % self.width);
            for b in 0..d {
                let (yb, xb) = (b / self.width, b % self.width);
                let dy = ya as F - yb as F;
                let dx = xa as F - xb as F;
                data[a * d + b] = (dy * dy + dx * dx).sqrt();
            }
        }
        CostMatrix::from_rows(d, data)
    }

    /// Squared Euclidean distances — a *Euclidean distance matrix* in the
    /// Dattorro sense (footnote 1), as required by Property 2 for the
    /// Independence kernel to be negative definite.
    pub fn squared_cost_matrix(&self) -> CostMatrix {
        let m = self.cost_matrix();
        CostMatrix { d: m.d, data: m.data.iter().map(|v| v * v).collect() }
    }
}

/// The speed-benchmark workload of §5.3: d points drawn from a spherical
/// Gaussian in dimension max(1, d/10), pairwise Euclidean distances,
/// median-normalized "to obtain enough variability in the distance
/// matrix".
#[derive(Debug, Clone, Copy)]
pub struct RandomMetric {
    d: usize,
}

impl RandomMetric {
    pub fn new(d: usize) -> Self {
        assert!(d > 1);
        Self { d }
    }

    /// Draw the cost matrix (deterministic in the RNG state).
    pub fn sample(&self, rng: &mut Rng) -> CostMatrix {
        let ambient = (self.d / 10).max(1);
        let pts: Vec<Vec<F>> = (0..self.d)
            .map(|_| (0..ambient).map(|_| rng.normal()).collect())
            .collect();
        let mut data = vec![0.0; self.d * self.d];
        for i in 0..self.d {
            for j in (i + 1)..self.d {
                let dist: F = pts[i]
                    .iter()
                    .zip(&pts[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<F>()
                    .sqrt();
                data[i * self.d + j] = dist;
                data[j * self.d + i] = dist;
            }
        }
        CostMatrix::from_rows(self.d, data).median_normalized()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simplex::seeded_rng;

    #[test]
    fn grid_metric_basics() {
        let g = GridMetric::new(2, 3);
        let m = g.cost_matrix();
        assert_eq!(m.dim(), 6);
        // Pixel 0=(0,0), pixel 1=(0,1): distance 1.
        assert_eq!(m.get(0, 1), 1.0);
        // Pixel 0=(0,0), pixel 5=(1,2): sqrt(1+4).
        assert!((m.get(0, 5) - (5.0 as F).sqrt()).abs() < 1e-12);
        assert!(is_metric_matrix(&m, 1e-9).is_ok());
    }

    #[test]
    fn grid_metric_is_symmetric_zero_diag() {
        let m = GridMetric::new(4, 4).cost_matrix();
        for i in 0..16 {
            assert_eq!(m.get(i, i), 0.0);
            for j in 0..16 {
                assert_eq!(m.get(i, j), m.get(j, i));
            }
        }
    }

    #[test]
    fn random_metric_is_a_metric() {
        let mut rng = seeded_rng(0);
        let m = RandomMetric::new(30).sample(&mut rng);
        assert!(is_metric_matrix(&m, 1e-9).is_ok());
        // Median normalization: off-diagonal median == 1.
        assert!((m.median_cost() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn powf_preserves_metric_for_small_exponents() {
        // M^a for a in (0,1] keeps triangle inequalities (subadditivity of
        // t -> t^a); checked numerically here, cited analytically in docs.
        let mut rng = seeded_rng(1);
        let m = RandomMetric::new(20).sample(&mut rng);
        for &a in &[0.01, 0.1, 0.5, 1.0] {
            assert!(
                is_metric_matrix(&m.powf(a), 1e-9).is_ok(),
                "M^{a} left the metric cone"
            );
        }
    }

    #[test]
    fn median_normalized_idempotent_on_zero() {
        let z = CostMatrix::from_rows(2, vec![0.0; 4]);
        assert_eq!(z.median_normalized(), z);
    }

    #[test]
    fn plan_cost_matches_manual() {
        let m = CostMatrix::from_rows(2, vec![0., 1., 1., 0.]);
        let plan = vec![0.5, 0.0, 0.25, 0.25];
        assert!((m.plan_cost(&plan) - 0.25).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_costs() {
        CostMatrix::from_rows(2, vec![0.0, F::NAN, 1.0, 0.0]);
    }
}
