//! Metric-cone membership checks (paper §2.2).
//!
//! d_M is a *distance* exactly when M lies in the cone
//! M = {M ∈ R₊^{d×d} : m_ii = 0; m_ij ≤ m_ik + m_kj} (Avis, 1980).
//! The harnesses validate their generated ground metrics through here, and
//! `theory_invariants.rs` uses the checker to set up Theorem 1 tests.

use super::CostMatrix;
use crate::F;

/// Why a matrix fails to be a metric matrix.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricViolation {
    NonzeroDiagonal(usize, F),
    Asymmetric(usize, usize, F, F),
    Triangle { i: usize, j: usize, k: usize, mij: F, sum: F },
}

impl std::fmt::Display for MetricViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricViolation::NonzeroDiagonal(i, v) => {
                write!(f, "diagonal entry m[{i},{i}] = {v} is nonzero")
            }
            MetricViolation::Asymmetric(i, j, a, b) => {
                write!(f, "asymmetry at ({i},{j}): {a} vs {b}")
            }
            MetricViolation::Triangle { i, j, k, mij, sum } => write!(
                f,
                "triangle violated: m[{i},{j}]={mij} > m[{i},{k}]+m[{k},{j}]={sum}"
            ),
        }
    }
}

impl std::error::Error for MetricViolation {}

/// Check membership of the metric cone up to tolerance `tol`.
pub fn is_metric_matrix(m: &CostMatrix, tol: F) -> Result<(), MetricViolation> {
    let d = m.dim();
    for i in 0..d {
        let mii = m.get(i, i);
        if mii.abs() > tol {
            return Err(MetricViolation::NonzeroDiagonal(i, mii));
        }
        for j in (i + 1)..d {
            let (a, b) = (m.get(i, j), m.get(j, i));
            if (a - b).abs() > tol {
                return Err(MetricViolation::Asymmetric(i, j, a, b));
            }
        }
    }
    for k in 0..d {
        let row_k = m.row(k);
        for i in 0..d {
            let mik = m.get(i, k);
            let row_i = m.row(i);
            for j in 0..d {
                let sum = mik + row_k[j];
                if row_i[j] > sum + tol {
                    return Err(MetricViolation::Triangle {
                        i,
                        j,
                        k,
                        mij: row_i[j],
                        sum,
                    });
                }
            }
        }
    }
    Ok(())
}

/// Largest triangle-inequality violation max_{ijk} (m_ij − m_ik − m_kj)₊.
/// Zero for metric matrices; used to quantify how far squared-Euclidean
/// costs (which are *not* metrics) sit outside the cone.
pub fn max_triangle_violation(m: &CostMatrix) -> F {
    let d = m.dim();
    let mut worst: F = 0.0;
    for k in 0..d {
        let row_k = m.row(k);
        for i in 0..d {
            let mik = m.get(i, k);
            let row_i = m.row(i);
            for j in 0..d {
                worst = worst.max(row_i[j] - mik - row_k[j]);
            }
        }
    }
    worst.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metric::GridMetric;

    #[test]
    fn accepts_grid_metric() {
        let m = GridMetric::new(3, 3).cost_matrix();
        assert_eq!(is_metric_matrix(&m, 1e-12), Ok(()));
        assert_eq!(max_triangle_violation(&m), 0.0);
    }

    #[test]
    fn detects_nonzero_diagonal() {
        let m = CostMatrix::from_rows(2, vec![0.5, 1.0, 1.0, 0.0]);
        assert!(matches!(
            is_metric_matrix(&m, 1e-12),
            Err(MetricViolation::NonzeroDiagonal(0, _))
        ));
    }

    #[test]
    fn detects_asymmetry() {
        let m = CostMatrix::from_rows(2, vec![0.0, 1.0, 2.0, 0.0]);
        assert!(matches!(
            is_metric_matrix(&m, 1e-12),
            Err(MetricViolation::Asymmetric(0, 1, _, _))
        ));
    }

    #[test]
    fn detects_triangle_violation() {
        // m(0,2)=10 > m(0,1)+m(1,2)=2.
        let m = CostMatrix::from_rows(
            3,
            vec![0., 1., 10., 1., 0., 1., 10., 1., 0.],
        );
        let err = is_metric_matrix(&m, 1e-12).unwrap_err();
        assert!(matches!(err, MetricViolation::Triangle { .. }));
        assert!((max_triangle_violation(&m) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn squared_grid_distances_are_not_metric() {
        // The classic fact motivating footnote 1: squared Euclidean
        // distances violate the triangle inequality...
        let m2 = GridMetric::new(1, 4).squared_cost_matrix();
        assert!(is_metric_matrix(&m2, 1e-9).is_err());
        assert!(max_triangle_violation(&m2) > 0.0);
        // ...but their square root (the 0.5 power) is a metric again.
        assert!(is_metric_matrix(&m2.powf(0.5), 1e-9).is_ok());
    }
}
