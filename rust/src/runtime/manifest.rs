//! Artifact manifest: discovery of the AOT-compiled HLO variants.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every lowered variant; this module parses it (with the in-tree JSON
//! parser) and answers shape-class queries for the runtime and the
//! coordinator's router.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Kernel flavor of an artifact (see aot.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Flavor {
    /// Inner products lowered through the Pallas kernel (interpret mode).
    Pallas,
    /// Plain jnp contractions (XLA-fused GEMMs) — the serving default.
    Xla,
}

impl Flavor {
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavor::Pallas => "pallas",
            Flavor::Xla => "xla",
        }
    }
}

/// One AOT-lowered program variant.
#[derive(Debug, Clone)]
pub struct ArtifactVariant {
    /// Unique name (also the HLO file stem).
    pub name: String,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
    /// Histogram dimension d.
    pub d: usize,
    /// Batch width N.
    pub n: usize,
    /// Fixed iteration count baked into the program.
    pub iters: usize,
    /// Kernel flavor.
    pub flavor: Flavor,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: Vec<ArtifactVariant>,
    pub dir: PathBuf,
}

/// Manifest loading errors.
#[derive(Debug)]
pub enum ManifestError {
    Io(PathBuf, std::io::Error),
    Parse(crate::util::json::JsonError),
    Schema(&'static str),
    Version(usize),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(path, e) => {
                write!(f, "cannot read manifest {}: {e}", path.display())
            }
            ManifestError::Parse(e) => write!(f, "cannot parse manifest: {e}"),
            ManifestError::Schema(field) => {
                write!(f, "manifest field missing or malformed: {field}")
            }
            ManifestError::Version(v) => write!(f, "unsupported manifest version {v}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(_, e) => Some(e),
            ManifestError::Parse(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for ManifestError {
    fn from(e: crate::util::json::JsonError) -> Self {
        ManifestError::Parse(e)
    }
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| ManifestError::Io(path.clone(), e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (directory is used to resolve file paths).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, ManifestError> {
        let doc = Json::parse(text)?;
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or(ManifestError::Schema("version"))?;
        if version != 1 {
            return Err(ManifestError::Version(version));
        }
        let raw = doc
            .get("variants")
            .and_then(Json::as_array)
            .ok_or(ManifestError::Schema("variants"))?;
        let mut variants = Vec::with_capacity(raw.len());
        for v in raw {
            let name = v
                .get("name")
                .and_then(Json::as_str)
                .ok_or(ManifestError::Schema("variant.name"))?
                .to_string();
            let file = v
                .get("file")
                .and_then(Json::as_str)
                .ok_or(ManifestError::Schema("variant.file"))?;
            let d = v
                .get("d")
                .and_then(Json::as_usize)
                .ok_or(ManifestError::Schema("variant.d"))?;
            let n = v
                .get("n")
                .and_then(Json::as_usize)
                .ok_or(ManifestError::Schema("variant.n"))?;
            let iters = v
                .get("iters")
                .and_then(Json::as_usize)
                .ok_or(ManifestError::Schema("variant.iters"))?;
            let flavor = match v.get("flavor").and_then(Json::as_str) {
                Some("pallas") => Flavor::Pallas,
                Some("xla") => Flavor::Xla,
                _ => return Err(ManifestError::Schema("variant.flavor")),
            };
            variants.push(ArtifactVariant {
                name,
                path: dir.join(file),
                d,
                n,
                iters,
                flavor,
            });
        }
        Ok(Self { variants, dir })
    }

    /// The distinct dimensions available for a flavor (sorted).
    pub fn dims(&self, flavor: Flavor) -> Vec<usize> {
        let mut ds: Vec<usize> = self
            .variants
            .iter()
            .filter(|v| v.flavor == flavor)
            .map(|v| v.d)
            .collect();
        ds.sort_unstable();
        ds.dedup();
        ds
    }

    /// Pick the variant for dimension `d` whose batch width best fits
    /// `batch` (smallest n ≥ batch, else the largest available n).
    pub fn select(&self, d: usize, batch: usize, flavor: Flavor) -> Option<&ArtifactVariant> {
        let mut candidates: Vec<&ArtifactVariant> = self
            .variants
            .iter()
            .filter(|v| v.d == d && v.flavor == flavor)
            .collect();
        if candidates.is_empty() {
            return None;
        }
        candidates.sort_by_key(|v| v.n);
        candidates
            .iter()
            .find(|v| v.n >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1, "dtype": "f32", "fingerprint": "x", "config": {},
        "variants": [
            {"name": "a", "file": "a.hlo.txt", "d": 16, "n": 1, "iters": 20, "flavor": "xla"},
            {"name": "b", "file": "b.hlo.txt", "d": 16, "n": 16, "iters": 20, "flavor": "xla"},
            {"name": "c", "file": "c.hlo.txt", "d": 64, "n": 64, "iters": 20, "flavor": "xla"},
            {"name": "p", "file": "p.hlo.txt", "d": 16, "n": 1, "iters": 20, "flavor": "pallas"}
        ]
    }"#;

    #[test]
    fn parses_and_selects() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.variants.len(), 4);
        assert_eq!(m.dims(Flavor::Xla), vec![16, 64]);
        assert_eq!(m.dims(Flavor::Pallas), vec![16]);
        // batch 4 at d=16 -> n=16 variant (smallest n >= 4).
        assert_eq!(m.select(16, 4, Flavor::Xla).unwrap().name, "b");
        // batch 1 -> exact n=1.
        assert_eq!(m.select(16, 1, Flavor::Xla).unwrap().name, "a");
        // batch 100 at d=64 -> largest available (64).
        assert_eq!(m.select(64, 100, Flavor::Xla).unwrap().name, "c");
        // missing dimension.
        assert!(m.select(128, 1, Flavor::Xla).is_none());
        // path resolution.
        assert_eq!(
            m.variants[0].path,
            PathBuf::from("/tmp/a/a.hlo.txt")
        );
    }

    #[test]
    fn schema_errors() {
        assert!(matches!(
            Manifest::parse("{}", PathBuf::new()),
            Err(ManifestError::Schema("version"))
        ));
        assert!(matches!(
            Manifest::parse(r#"{"version": 2, "variants": []}"#, PathBuf::new()),
            Err(ManifestError::Version(2))
        ));
        let bad = r#"{"version": 1, "variants": [{"name": "a"}]}"#;
        assert!(matches!(
            Manifest::parse(bad, PathBuf::new()),
            Err(ManifestError::Schema("variant.file"))
        ));
    }

    #[test]
    fn real_manifest_loads() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(!m.variants.is_empty());
            // The default aot grid always contains d=400 (the MNIST shape).
            assert!(m.dims(Flavor::Xla).contains(&400));
            for v in &m.variants {
                assert!(v.path.exists(), "missing artifact {:?}", v.path);
            }
        }
    }
}
