//! XLA/PJRT execution runtime — loads and runs the AOT artifacts.
//!
//! This is the bottom of the Layer-3 stack: it wraps a PJRT CPU client
//! (through the [`pjrt`] binding surface), discovers the HLO-text
//! artifacts via the [`manifest`], compiles each variant **once**
//! (lazily, cached), and executes batched Sinkhorn programs with zero
//! Python anywhere near the call. Interchange is HLO *text* because
//! xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit-instruction-id
//! serialized protos; the text parser reassigns ids (see
//! `python/compile/aot.py`). Builds without the native library use the
//! in-tree [`pjrt`] shim, which fails client construction cleanly so the
//! coordinator serves everything on the CPU engines.
//!
//! The artifact signature is
//!   `f(M: f32[d,d], lam: f32[], R: f32[d,n], C: f32[d,n])
//!      -> (dist: f32[n], err: f32[])`
//! with `iters` fixed at lowering time.

mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactVariant, Flavor, Manifest, ManifestError};

// The PJRT binding layer. `runtime::pjrt` mirrors the `xla` crate's API
// surface one-to-one so a vendored xla_extension build can be swapped in
// by changing this single alias; by default it is the in-tree no-backend
// shim (every client construction fails cleanly and the coordinator
// falls back to the CPU engines).
use self::pjrt as xla;

use crate::metric::CostMatrix;
use crate::F;
use std::collections::HashMap;
use std::path::Path;

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    Manifest(ManifestError),
    Xla(String),
    NoVariant { d: usize, flavor: Flavor, available: Vec<usize> },
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Manifest(e) => write!(f, "{e}"),
            RuntimeError::Xla(msg) => write!(f, "xla error: {msg}"),
            RuntimeError::NoVariant { d, flavor, available } => write!(
                f,
                "no artifact for d={d} flavor={flavor:?}; available dims: {available:?}"
            ),
            RuntimeError::Shape(msg) => write!(f, "shape mismatch: {msg}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Manifest(e) => Some(e),
            _ => None,
        }
    }
}

impl From<ManifestError> for RuntimeError {
    fn from(e: ManifestError) -> Self {
        RuntimeError::Manifest(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Result of one batched execution.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// d_M^λ(r_j, c_j) for each column pair, f64-widened.
    pub distances: Vec<F>,
    /// Max marginal violation reported by the program (diagnostic).
    pub marginal_error: F,
    /// Which artifact produced it.
    pub variant: String,
}

/// PJRT-backed Sinkhorn executor with a compiled-executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Device-resident cost matrices, keyed by caller-provided id + d.
    /// Staging M (d² floats) dominated per-call overhead before this
    /// cache was added.
    metric_buffers: HashMap<(u64, usize), xla::PjRtBuffer>,
    /// Cumulative executions per variant (observability).
    exec_counts: HashMap<String, u64>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self, RuntimeError> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
            metric_buffers: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Platform string of the PJRT backend (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Executions performed per variant so far.
    pub fn exec_counts(&self) -> &HashMap<String, u64> {
        &self.exec_counts
    }

    /// Number of compiled executables held in the cache.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }

    /// Select the best variant for (d, batch, flavor).
    pub fn select(
        &self,
        d: usize,
        batch: usize,
        flavor: Flavor,
    ) -> Result<ArtifactVariant, RuntimeError> {
        self.manifest
            .select(d, batch, flavor)
            .cloned()
            .ok_or_else(|| RuntimeError::NoVariant {
                d,
                flavor,
                available: self.manifest.dims(flavor),
            })
    }

    /// Compile (or fetch from cache) the executable for a variant.
    fn executable(
        &mut self,
        variant: &ArtifactVariant,
    ) -> Result<&xla::PjRtLoadedExecutable, RuntimeError> {
        if !self.cache.contains_key(&variant.name) {
            let proto = xla::HloModuleProto::from_text_file(&variant.path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(variant.name.clone(), exe);
        }
        Ok(&self.cache[&variant.name])
    }

    /// Pre-compile every variant of a flavor (warm start for serving).
    pub fn warmup(&mut self, flavor: Flavor) -> Result<usize, RuntimeError> {
        let variants: Vec<ArtifactVariant> = self
            .manifest
            .variants
            .iter()
            .filter(|v| v.flavor == flavor)
            .cloned()
            .collect();
        let count = variants.len();
        for v in &variants {
            self.executable(v)?;
        }
        Ok(count)
    }

    /// Drop any device-resident buffer cached under `metric_key` (call
    /// when the metric registered under a key is replaced).
    pub fn invalidate_metric(&mut self, metric_key: u64) {
        self.metric_buffers.retain(|(k, _), _| *k != metric_key);
    }

    /// Execute one batched Sinkhorn solve.
    ///
    /// `r_cols` / `c_cols` hold `batch ≤ variant.n` histograms as columns
    /// in row-major (d, batch) order; they are padded to the variant's
    /// batch width with uniform histograms (whose results are discarded).
    pub fn execute(
        &mut self,
        variant: &ArtifactVariant,
        metric: &CostMatrix,
        lambda: F,
        r_cols: &[Vec<F>],
        c_cols: &[Vec<F>],
    ) -> Result<BatchOutput, RuntimeError> {
        self.execute_keyed(variant, metric, None, lambda, r_cols, c_cols)
    }

    /// [`Self::execute`] with a stable caller-assigned key for `metric`,
    /// enabling the device-buffer cache: M (d² floats, the largest input
    /// by far) is uploaded once per (key, d) instead of once per call.
    /// The caller owns key semantics — reusing a key for a *different*
    /// matrix without [`Self::invalidate_metric`] serves stale costs.
    pub fn execute_keyed(
        &mut self,
        variant: &ArtifactVariant,
        metric: &CostMatrix,
        metric_key: Option<u64>,
        lambda: F,
        r_cols: &[Vec<F>],
        c_cols: &[Vec<F>],
    ) -> Result<BatchOutput, RuntimeError> {
        let d = variant.d;
        let n = variant.n;
        if metric.dim() != d {
            return Err(RuntimeError::Shape(format!(
                "metric dim {} != artifact d {}",
                metric.dim(),
                d
            )));
        }
        if r_cols.len() != c_cols.len() {
            return Err(RuntimeError::Shape(format!(
                "r batch {} != c batch {}",
                r_cols.len(),
                c_cols.len()
            )));
        }
        let batch = r_cols.len();
        if batch == 0 || batch > n {
            return Err(RuntimeError::Shape(format!(
                "batch {batch} out of range 1..={n}"
            )));
        }
        for (k, (r, c)) in r_cols.iter().zip(c_cols).enumerate() {
            if r.len() != d || c.len() != d {
                return Err(RuntimeError::Shape(format!(
                    "pair {k}: histogram dims ({}, {}) != d {d}",
                    r.len(),
                    c.len()
                )));
            }
        }

        // Stage inputs as device buffers. Histograms go in column-major
        // logical layout (d, n) == row-major rows over d. The cost matrix
        // — the dominant transfer at d² floats — is cached on device when
        // the caller supplies a stable key.
        let mut r_f32 = vec![1.0f32 / d as f32; d * n];
        let mut c_f32 = vec![1.0f32 / d as f32; d * n];
        for (j, (r, c)) in r_cols.iter().zip(c_cols).enumerate() {
            for i in 0..d {
                r_f32[i * n + j] = r[i] as f32;
                c_f32[i * n + j] = c[i] as f32;
            }
        }

        // Ensure the executable and (optionally) the cached metric buffer
        // exist before taking shared borrows for the call itself.
        self.executable(variant)?;
        let cache_slot = metric_key.map(|k| (k, d));
        if let Some(slot) = cache_slot {
            if !self.metric_buffers.contains_key(&slot) {
                let m_f32 = metric.to_f32();
                let buf =
                    self.client.buffer_from_host_buffer(&m_f32, &[d, d], None)?;
                self.metric_buffers.insert(slot, buf);
            }
        }
        let m_owned; // keeps an uncached upload alive through the call
        let m_buf: &xla::PjRtBuffer = match cache_slot {
            Some(slot) => &self.metric_buffers[&slot],
            None => {
                let m_f32 = metric.to_f32();
                m_owned =
                    self.client.buffer_from_host_buffer(&m_f32, &[d, d], None)?;
                &m_owned
            }
        };
        let lam_buf =
            self.client.buffer_from_host_buffer(&[lambda as f32], &[], None)?;
        let r_buf = self.client.buffer_from_host_buffer(&r_f32, &[d, n], None)?;
        let c_buf = self.client.buffer_from_host_buffer(&c_f32, &[d, n], None)?;

        let exe = &self.cache[&variant.name];
        let result =
            exe.execute_b::<&xla::PjRtBuffer>(&[m_buf, &lam_buf, &r_buf, &c_buf])?;
        let out = result[0][0].to_literal_sync()?;
        let (dist_lit, err_lit) = out.to_tuple2()?;
        let dist32 = dist_lit.to_vec::<f32>()?;
        let err = err_lit.to_vec::<f32>()?.first().copied().unwrap_or(f32::NAN);

        *self.exec_counts.entry(variant.name.clone()).or_insert(0) += 1;

        Ok(BatchOutput {
            distances: dist32.iter().take(batch).map(|&x| x as F).collect(),
            marginal_error: err as F,
            variant: variant.name.clone(),
        })
    }

    /// Convenience: solve r vs many targets with automatic variant choice,
    /// chunking the batch across executions when it exceeds the widest
    /// artifact.
    pub fn distances(
        &mut self,
        metric: &CostMatrix,
        lambda: F,
        r: &crate::simplex::Histogram,
        cs: &[crate::simplex::Histogram],
        flavor: Flavor,
    ) -> Result<Vec<F>, RuntimeError> {
        let d = metric.dim();
        let mut out = Vec::with_capacity(cs.len());
        let mut idx = 0;
        while idx < cs.len() {
            let remaining = cs.len() - idx;
            let variant = self.select(d, remaining, flavor)?;
            let take = remaining.min(variant.n);
            let r_cols: Vec<Vec<F>> =
                (0..take).map(|_| r.values().to_vec()).collect();
            let c_cols: Vec<Vec<F>> = cs[idx..idx + take]
                .iter()
                .map(|c| c.values().to_vec())
                .collect();
            let batch = self.execute(&variant, metric, lambda, &r_cols, &c_cols)?;
            out.extend(batch.distances);
            idx += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // The runtime requires real artifacts + libxla_extension, so numeric
    // coverage lives in `rust/tests/runtime_artifacts.rs` (integration).
    // Here we only test pure logic.
    use super::*;

    #[test]
    fn error_display() {
        let e = RuntimeError::NoVariant { d: 7, flavor: Flavor::Xla, available: vec![16] };
        let s = e.to_string();
        assert!(s.contains("d=7"));
        assert!(s.contains("[16]"));
    }
}
