//! PJRT binding surface — API-compatible shim for the `xla` crate.
//!
//! The serving stack was written against the `xla` crate's PJRT CPU
//! client (xla_extension 0.5.1). That binding needs a vendored native
//! `libxla_extension`, which is not part of this repository and cannot be
//! fetched in the offline/CI build. This module mirrors the exact API
//! subset [`super::XlaRuntime`] consumes, with one behavioral change:
//! [`PjRtClient::cpu`] reports that no PJRT backend is linked. Callers
//! already handle runtime-construction failure (the coordinator falls
//! back to the pure-Rust engines; `repro info` prints the error), so the
//! whole crate builds, tests and serves without the native library.
//!
//! Swapping a real binding back in is a one-line change: `use pjrt as
//! xla;` in [`super`] becomes `use xla;` once the dependency exists.

use std::path::Path;

/// Error type mirroring `xla::Error` (message-only).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT backend is not linked into this build (the in-tree \
         runtime::pjrt shim is active); CPU engines serve all queries"
            .to_string(),
    )
}

/// PJRT client handle (shim: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

/// Host-side literal (tuple or typed array).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl PjRtClient {
    /// Create the CPU PJRT client. The shim has no backend to create, so
    /// this always returns an error; callers fall back to CPU engines.
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    /// Platform string of the backend (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }

    /// Stage a host f32 buffer on device.
    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    /// Parse an HLO text file into a module proto.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, Error> {
        Err(unavailable())
    }
}

impl XlaComputation {
    /// Wrap a module proto as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed buffer arguments; returns per-device,
    /// per-output buffers.
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal, blocking.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

impl Literal {
    /// Destructure a 2-tuple literal.
    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        Err(unavailable())
    }

    /// Read out a typed element buffer.
    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend"));
    }
}
