//! The paper's §5.1 experiment, end to end: SVM classification of digit
//! histograms under eight candidate distances (Figure 2), on the
//! synthetic-digits substitute (see README.md §Workloads).
//!
//! Prints a couple of rendered digits, then the full protocol's table:
//! mean ± std test error per distance per training-set size.
//!
//! ```bash
//! cargo run --release --example mnist_classification             # ~minutes
//! cargo run --release --example mnist_classification -- --quick  # seconds
//! ```

use sinkhorn_rs::data::{DigitClass, DigitConfig};
use sinkhorn_rs::exp::fig2;
use sinkhorn_rs::prelude::*;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");

    // Show what the workload looks like.
    let gen = SyntheticDigits::new(DigitConfig { grid: 12, ..Default::default() });
    let mut rng = seeded_rng(3);
    for class in [3usize, 8] {
        let s = gen.sample(DigitClass(class), &mut rng);
        println!("a synthetic '{class}' (d = {}):\n{}", s.histogram.dim(), gen.ascii(&s.histogram));
    }

    let config = if quick {
        fig2::Fig2Config {
            grid: 8,
            ns: vec![60],
            repeats: 1,
            distances: vec![
                fig2::DistanceKind::Classical(ClassicalDistance::Hellinger),
                fig2::DistanceKind::Classical(ClassicalDistance::SquaredEuclidean),
                fig2::DistanceKind::Independence,
                fig2::DistanceKind::Sinkhorn,
            ],
            ..Default::default()
        }
    } else {
        fig2::Fig2Config::default() // grid 12 (d=144), all 8 distances, EMD included
    };

    eprintln!(
        "running the Figure 2 protocol: d={}, ns={:?}, {} folds x {} repeats\n\
         (1 fold train / {} folds test; t in {{1,q10,q20,q50}}; C in 10^{{-2:2:4}};\n\
         sinkhorn lambda in {{5,7,9,11}}/q50(M) x 20 iterations)",
        config.grid * config.grid,
        config.ns,
        config.folds,
        config.repeats,
        config.folds - 1,
    );
    let t0 = std::time::Instant::now();
    let points = fig2::run(&config);
    println!("{}", fig2::render(&points));
    eprintln!("total {:.1}s", t0.elapsed().as_secs_f64());

    // The paper's headline: Sinkhorn beats the classical distances.
    for &n in &config.ns {
        let err = |name: &str| {
            points
                .iter()
                .find(|p| p.n == n && p.distance == name)
                .map(|p| p.mean_error)
        };
        if let (Some(sk), Some(eu)) = (err("sinkhorn"), err("sq_euclidean")) {
            println!(
                "n={n}: sinkhorn {:.3} vs sq_euclidean {:.3} -> {}",
                sk,
                eu,
                if sk <= eu { "sinkhorn wins/ties (paper's claim)" } else { "baseline wins here" }
            );
        }
    }
}
