//! Quickstart: the core API in ~60 lines.
//!
//! Builds a ground metric, samples histograms, and compares the exact
//! optimal transportation distance (network simplex) with the Sinkhorn
//! distance at several λ — the paper's Definition 1 / Equation (2) pair.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use sinkhorn_rs::prelude::*;

fn main() {
    // Ground metric: Euclidean distances on an 8x8 pixel grid (d = 64).
    let grid = GridMetric::new(8, 8);
    let metric = grid.cost_matrix();
    println!(
        "ground metric: {}x{} grid -> d = {} (median cost q50 = {:.3})",
        8,
        8,
        metric.dim(),
        metric.median_cost()
    );

    // Two random histograms on the simplex.
    let mut rng = seeded_rng(7);
    let r = Histogram::sample_uniform(64, &mut rng);
    let c = Histogram::sample_uniform(64, &mut rng);

    // Exact optimal transportation distance (the EMD baseline).
    let plan = EmdSolver::new(&metric).solve(&r, &c).expect("solve");
    println!(
        "exact EMD: d_M(r,c) = {:.6}   ({} pivots, {} nonzeros in P*, dual gap {:.1e})",
        plan.cost,
        plan.stats.pivots,
        plan.support_size(),
        plan.dual_violation(&metric),
    );

    // Sinkhorn distances: smoothed, always >= the exact value, and
    // converging to it as lambda grows (paper Fig. 3).
    println!("\n{:>8} {:>12} {:>12} {:>8}", "lambda", "d_M^l(r,c)", "rel gap", "iters");
    for lambda in [1.0, 3.0, 9.0, 27.0, 81.0] {
        let engine = SinkhornEngine::new(&metric, lambda);
        let out = engine.distance(&r, &c);
        println!(
            "{lambda:>8.1} {:>12.6} {:>11.1}% {:>8}",
            out.value,
            100.0 * (out.value - plan.cost) / plan.cost,
            out.stats.iterations
        );
    }

    // The alpha = 0 extreme: the Independence kernel r^T M c (Property 2).
    let m2 = grid.squared_cost_matrix();
    println!(
        "\nindependence kernel d_{{M^2,0}}(r,c) = r'Mc = {:.6}",
        independence_distance(&m2, &r, &c)
    );

    // Classical baselines for scale.
    for d in ClassicalDistance::ALL {
        println!("{:>18}: {:.6}", d.name(), d.eval(&r, &c));
    }
}
