//! End-to-end serving demo: the Layer-3 coordinator dispatching batched
//! distance queries to AOT-compiled XLA artifacts over PJRT — Python
//! nowhere on the request path.
//!
//! Four concurrent client threads issue randomized queries against two
//! registered ground metrics and two λ values (four shape classes); the
//! dynamic batcher coalesces them into vectorized executions. The demo
//! prints per-class routing, latency and batch-occupancy statistics,
//! cross-checks a sample of results against the CPU engine, and finishes
//! with the retrieval path: a clustered corpus is ingested
//! (`register_corpus`) and served top-k queries through the pruned
//! bound-then-refine cascade, with prune/recall statistics. Tracing is
//! on for every query (PR 9): the demo prints the per-stage latency
//! breakdown and exports the last retrieval's span tree to
//! `trace_demo.json` for Perfetto. Telemetry is on too (PR 10): the
//! demo binds the Prometheus scrape server on an ephemeral localhost
//! port, prints the URL, self-scrapes `/metrics` at the end and prints
//! the windowed per-tenant SLO report.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! # or, without artifacts (CPU-only serving, same request path):
//! cargo run --release --example serve_demo
//! ```

use sinkhorn_rs::coordinator::{
    BatcherConfig, CoordinatorConfig, CorpusId, DistanceService, EngineKind,
    MetricId, Query, RetrievalQuery, WarmStartConfig,
};
use sinkhorn_rs::prelude::*;
use sinkhorn_rs::sinkhorn::{LambdaSchedule, SinkhornConfig, SolveBudget};
use sinkhorn_rs::telemetry::http_get;
use sinkhorn_rs::trace::{chrome_trace, Stage};
use std::time::{Duration, Instant};

fn main() {
    let artifact_dir = std::path::PathBuf::from("artifacts");
    let artifacts = artifact_dir.join("manifest.json").exists();
    if !artifacts {
        eprintln!(
            "no artifacts/ found (run `make artifacts` for the XLA path) — \
             serving CPU-only"
        );
    }

    // Start the service with a 64-wide batcher and a 2 ms deadline.
    // CPU-served shape classes get convergence control: per-worker
    // warm-start stores (repeated query pairs re-converge in a couple of
    // iterations) and geometric ε-scaling for cold high-λ solves.
    // Retrieval runs on the dedicated runtime thread over a 3-shard
    // corpus partition, probing every 4th query against the merged
    // brute force so the recall gauge is live.
    let service = DistanceService::start(CoordinatorConfig {
        artifact_dir: artifacts.then_some(artifact_dir),
        batcher: BatcherConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            ..BatcherConfig::default()
        },
        warm_start: Some(WarmStartConfig::default()),
        anneal: LambdaSchedule::geometric(1.0),
        retrieval_probe_every: 4,
        retrieval_shards: 3,
        // PR 9: trace every query (a demo wants a full picture; serving
        // defaults sample 1/64) so the stage table below is dense and
        // the exported flame graph always exists.
        trace: Some(TraceConfig { sample_every: 1, ring_capacity: 4096 }),
        // PR 10: bind the Prometheus exporter on an ephemeral localhost
        // port with 6 x 10s rollup windows and a lenient latency SLO —
        // the demo's point is the live report, not actual shedding.
        telemetry: Some(TelemetryConfig {
            bind: "127.0.0.1:0".into(),
            window: Duration::from_secs(10),
            windows: 6,
            slo: Some(SloPolicy {
                p99_latency: Duration::from_millis(250),
                ..SloPolicy::default()
            }),
        }),
        ..Default::default()
    })
    .expect("service start");
    let scrape = service.scrape_addr().expect("telemetry exporter bound");
    println!(
        "telemetry: scrape http://{scrape}/metrics (also /healthz, /snapshot, /slo)"
    );

    // Two ground metrics: a 64-dim random metric (served by XLA) and a
    // 100-dim one (no artifact -> CPU fallback), demonstrating routing.
    let mut rng = seeded_rng(0);
    let m64 = RandomMetric::new(64).sample(&mut rng);
    let m100 = RandomMetric::new(100).sample(&mut rng);
    service.register_metric(MetricId(0), m64.clone()).unwrap();
    service.register_metric(MetricId(1), m100.clone()).unwrap();
    let compiled = service.warmup().expect("warmup");
    println!("compiled {compiled} XLA variants up front");

    // Four client threads, 64 queries each, mixed shape classes.
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let client = service.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = seeded_rng(1000 + t);
            let mut xla = 0usize;
            let mut cpu = 0usize;
            let mut lat_us = Vec::new();
            for k in 0..64 {
                let (metric, d) = if k % 4 == 0 {
                    (MetricId(1), 100)
                } else {
                    (MetricId(0), 64)
                };
                let lambda = if k % 2 == 0 { 9.0 } else { 1.0 };
                let r = Histogram::sample_uniform(d, &mut rng);
                let c = Histogram::sample_uniform(d, &mut rng);
                let res = client
                    .distance(Query::new(metric, lambda, r, c))
                    .expect("query");
                match res.engine {
                    EngineKind::Xla => xla += 1,
                    EngineKind::Cpu => cpu += 1,
                }
                lat_us.push(res.latency_us);
            }
            lat_us.sort_unstable();
            (xla, cpu, lat_us[lat_us.len() / 2])
        }));
    }
    for (t, h) in handles.into_iter().enumerate() {
        let (xla, cpu, p50) = h.join().unwrap();
        println!("client {t}: {xla} xla + {cpu} cpu responses, p50 latency {p50} us");
    }
    let elapsed = t0.elapsed();
    let stats = service.stats().unwrap();
    println!(
        "\n256 queries in {:.3}s ({:.0} q/s)\n{stats}",
        elapsed.as_secs_f64(),
        256.0 / elapsed.as_secs_f64()
    );

    // Cross-check: service answers == direct CPU engine (20 iterations).
    let mut rng = seeded_rng(42);
    let r = Histogram::sample_uniform(64, &mut rng);
    let c = Histogram::sample_uniform(64, &mut rng);
    let served = service
        .distance(Query::new(MetricId(0), 9.0, r.clone(), c.clone()))
        .unwrap();
    let direct = SinkhornEngine::with_config(&m64, SinkhornConfig::fixed(9.0, 20))
        .distance(&r, &c);
    println!(
        "\ncross-check: service {:.6} vs direct engine {:.6} (rel {:.2e})",
        served.distance(),
        direct.value,
        (served.distance() - direct.value).abs() / direct.value
    );

    // Anytime tier (PR 6): the same query under a wall-clock deadline
    // comes back with a certified error interval — the exact d^λ is
    // guaranteed to sit inside [lo, hi] no matter where the budget cut
    // the iteration off.
    let rushed = service
        .distance(
            Query::new(MetricId(0), 9.0, r.clone(), c.clone())
                .with_budget(SolveBudget::deadline_in(Duration::from_micros(500))),
        )
        .unwrap();
    let iv = rushed.outcome.interval;
    println!(
        "anytime: 500µs deadline -> estimate {:.6} certified in [{:.6}, {:.6}] \
         (width {:.2e}) after {} iterations",
        rushed.distance(),
        iv.lo,
        iv.hi,
        iv.width(),
        rushed.outcome.iterations,
    );

    // Warm-start demonstration: replay one CPU-served query (d=100 has no
    // artifact) — the repeats hit the per-worker warm-start stores.
    let r100 = Histogram::sample_uniform(100, &mut rng);
    let c100 = Histogram::sample_uniform(100, &mut rng);
    for _ in 0..4 {
        service
            .distance(Query::new(MetricId(1), 9.0, r100.clone(), c100.clone()))
            .unwrap();
    }
    let stats = service.stats().unwrap();
    println!(
        "warm-start stores after replaying one CPU query 4x: \
         {} hits / {} misses (rate {:.2})",
        stats.warm_hits,
        stats.warm_misses,
        stats.warm_hit_rate()
    );

    // Retrieval: ingest a clustered corpus against the 100-dim metric
    // and serve top-k queries through the pruned cascade. The corpus is
    // partitioned into 3 shards on the retrieval runtime thread, so the
    // searches below never touch the engine thread's batching loop.
    let d = 100;
    let gen = ClusteredCorpus::new(d, 6, 25, 0.12);
    let (corpus, protos) = gen.generate(&mut rng);
    let indexed = service
        .register_corpus(CorpusId(0), MetricId(1), 9.0, corpus)
        .expect("corpus registration");
    println!("\nindexed a {indexed}-entry clustered corpus (d={d}, λ=9, 3 shards)");
    for (qi, proto) in protos.iter().take(4).enumerate() {
        let q = gen.mixture_at(proto, 0.12, &mut rng);
        let out = service
            .retrieve(RetrievalQuery { corpus: CorpusId(0), r: q, k: 5 })
            .expect("retrieval query");
        let near: Vec<usize> = out.hits.iter().map(|h| h.entry).collect();
        println!(
            "query near cluster {qi}: top-5 {near:?} (best d^λ {:.4}), solved \
             {} / pruned {} ({:.0}% pruned{}), {} µs",
            out.hits.first().map(|h| h.distance).unwrap_or(f64::NAN),
            out.report.solved,
            out.report.pruned,
            100.0 * out.report.pruned_fraction(),
            out.report
                .probe
                .map(|p| format!(", recall probe {}/{}", p.matched, p.k))
                .unwrap_or_default(),
            out.latency_us,
        );
    }
    let stats = service.stats().unwrap();
    println!(
        "\nretrieval gauges: {} queries, pruned fraction {:.2}, recall {:.3} \
         over {} probe(s)",
        stats.retrievals,
        stats.retrieval_pruned_fraction(),
        stats.recall(),
        stats.recall_probes,
    );

    // Incremental index updates (PR 5): insert a duplicate of a live
    // query, watch it win top-1, tombstone it, compact the shard — all
    // without re-registering the corpus or stalling the engine thread.
    let probe_q = gen.mixture_at(&protos[0], 0.12, &mut rng);
    let inserted = service
        .corpus_insert(CorpusId(0), probe_q.clone())
        .expect("corpus insert");
    let out = service
        .retrieve(RetrievalQuery { corpus: CorpusId(0), r: probe_q.clone(), k: 5 })
        .expect("post-insert retrieval");
    println!(
        "\ninserted entry {inserted} (a duplicate of the next query): top-1 is \
         now entry {} at d^λ {:.4}",
        out.hits[0].entry, out.hits[0].distance
    );
    let removed = service
        .corpus_tombstone(CorpusId(0), inserted)
        .expect("corpus tombstone");
    let compacted = service.corpus_compact(CorpusId(0)).expect("corpus compact");
    let out = service
        .retrieve(RetrievalQuery { corpus: CorpusId(0), r: probe_q, k: 5 })
        .expect("post-tombstone retrieval");
    println!(
        "tombstoned it (hit={removed}), compacted {compacted} shard(s); top-1 \
         is entry {} again, corpus back to {} live entries",
        out.hits[0].entry, out.report.corpus
    );

    // Per-corpus (PR 8) and per-shard retrieval gauges from the stats
    // snapshot.
    let stats = service.stats().unwrap();
    println!(
        "\nretrieval runtime: {} off-thread searches (walltime mean {} µs, \
         max {} µs), queue depth {}, head-of-line wait {} µs, fairness {:.2}",
        stats.retrieval_offthread,
        stats.retrieval_search_mean_us,
        stats.retrieval_search_max_us,
        stats.retrieval_queue_depth,
        stats.retrieval_hol_blocked_us,
        stats.retrieval_fairness(),
    );
    for c in &stats.retrieval_shards {
        println!(
            "  corpus {}: queue depth {}, {} searches, {} µs waited in its \
             mailbox",
            c.corpus, c.queue_depth, c.searches, c.hol_blocked_us,
        );
        for g in &c.shards {
            println!(
                "    shard {}: {} live / {} slots (tombstone fraction {:.2}), \
                 {} insert(s), {} compaction(s), {} searches, last search {} µs",
                g.shard,
                g.live,
                g.entries,
                g.tombstone_fraction,
                g.inserts,
                g.compactions,
                g.searches,
                g.last_search_us,
            );
        }
    }

    // End-to-end tracing (PR 9): every query above was sampled. The
    // snapshot's stage table decomposes latency per pipeline stage and
    // tenant; the last retrieval's full span tree is exported as Chrome
    // trace-event JSON — load trace_demo.json at https://ui.perfetto.dev
    // (or chrome://tracing) to see one query as a flame graph.
    println!("\nstage breakdown (per-stage span-duration quantiles, µs):");
    for row in &stats.stages {
        println!(
            "  {:>8}[{}]: n={} p50~{} p99~{} max={}",
            row.stage, row.tenant, row.count, row.p50_us, row.p99_us, row.max_us,
        );
    }
    println!(
        "traces: {} sampled, {} spans collected, {} dropped",
        stats.traces_sampled, stats.trace_spans, stats.trace_spans_dropped,
    );
    let sink = service.trace_sink().expect("tracing is on in this demo");
    let spans = sink.sampled_spans();
    if let Some(root) = spans.iter().rev().find(|s| s.stage == Stage::Retrieve) {
        let tree: Vec<_> =
            spans.iter().copied().filter(|s| s.trace == root.trace).collect();
        let doc = chrome_trace(&tree);
        match std::fs::write("trace_demo.json", format!("{doc}\n")) {
            Ok(()) => println!(
                "exported the last retrieval's {} spans to trace_demo.json",
                tree.len(),
            ),
            Err(e) => eprintln!("could not write trace_demo.json: {e}"),
        }
    }

    // Telemetry (PR 10): self-scrape the live exporter. /metrics serves
    // the cumulative registry in Prometheus text exposition v0.0.4 —
    // point a real Prometheus at the URL printed above to chart these.
    println!("\ntelemetry scrape http://{scrape}/metrics:");
    match http_get(scrape, "/metrics", Duration::from_secs(5)) {
        Ok((200, body)) => {
            let mut shown = 0usize;
            for line in body.lines() {
                let keep = line.starts_with("sinkhorn_queries_total")
                    || line.starts_with("sinkhorn_retrievals_total")
                    || line.starts_with("sinkhorn_errors_total")
                    || line.starts_with("sinkhorn_deadline_misses_total")
                    || line.starts_with("sinkhorn_budget_sheds_total")
                    || line.starts_with("sinkhorn_tenant_queries_total")
                    || line.starts_with("sinkhorn_tenant_searches_total");
                if keep {
                    println!("  {line}");
                    shown += 1;
                }
            }
            let total = body.lines().filter(|l| !l.starts_with('#')).count();
            println!("  ... ({shown} of {total} series shown)");
        }
        Ok((code, _)) => eprintln!("  /metrics returned HTTP {code}"),
        Err(e) => eprintln!("  /metrics scrape failed: {e}"),
    }

    // The windowed SLO report: per-tenant sliding-window miss rates,
    // latency quantiles, and burn-rate gauges over the rollup ring.
    match http_get(scrape, "/slo", Duration::from_secs(5)) {
        Ok((200, body)) => println!("\nwindowed SLO report:\n  {}", body.trim_end()),
        Ok((code, _)) => eprintln!("/slo returned HTTP {code}"),
        Err(e) => eprintln!("/slo scrape failed: {e}"),
    }

    service.shutdown();
}
