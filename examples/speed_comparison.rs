//! The paper's §5.3 headline ("several orders of magnitude faster"):
//! seconds-per-distance for the exact EMD solver vs Sinkhorn on the CPU
//! vs the batched AOT/XLA runtime, over growing dimension (Figure 4),
//! followed by the §5.4 empirical-complexity sweep (Figure 5).
//!
//! ```bash
//! make artifacts && cargo run --release --example speed_comparison
//! cargo run --release --example speed_comparison -- --quick
//! ```

use sinkhorn_rs::exp::{fig4, fig5};
use sinkhorn_rs::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let artifacts = std::path::PathBuf::from("artifacts");
    let artifact_dir = artifacts.join("manifest.json").exists().then_some(artifacts);
    if artifact_dir.is_none() {
        eprintln!("note: no artifacts/ — the XLA column will be skipped");
    }

    // --- Figure 4: wallclock per distance ---
    let f4 = fig4::Fig4Config {
        dims: if quick { vec![64, 128] } else { vec![64, 128, 256, 512] },
        bench: if quick {
            Bench { warmup: 0, max_samples: 3, budget_secs: 5.0 }
        } else {
            Bench { warmup: 1, max_samples: 9, budget_secs: 20.0 }
        },
        artifact_dir,
        ..Default::default()
    };
    eprintln!("Figure 4 sweep over d = {:?} ...", f4.dims);
    let pts = fig4::run(&f4);
    println!("{}", fig4::render(&pts));

    // --- Figure 5: iterations to converge ---
    let f5 = fig5::Fig5Config {
        dims: if quick { vec![64, 128] } else { vec![64, 128, 256, 512] },
        trials: if quick { 3 } else { 8 },
        ..Default::default()
    };
    eprintln!("Figure 5 sweep over d = {:?} ...", f5.dims);
    let pts = fig5::run(&f5);
    println!("{}", fig5::render(&pts));
}
